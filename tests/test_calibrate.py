"""Algorithm 1 calibration: window narrowing, optimality, joint threading."""
import jax.numpy as jnp
import numpy as np

from repro.core import calibrate as C
from repro.core import qscheme as Q


def _lin(x, w, b):
    return x @ w + (b if b is not None else 0)


def test_search_window_matches_eq6():
    w = jnp.asarray([0.0, 3.0])  # max=3 -> ceil(log2(4)) + 1 = 3
    lo, hi = Q.search_window(w, tau=4)
    assert hi == 3 and lo == -1


def test_calibration_beats_extreme_choices():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 16)) * 0.05, jnp.float32)
    b = jnp.asarray(rng.normal(size=(16,)) * 0.1, jnp.float32)
    o_ref = _lin(x, w, b)
    res = C.calibrate_linear_module(x, w, b, o_ref, _lin)
    # compare against a clearly-too-coarse grid
    coarse = float(jnp.linalg.norm(
        o_ref - _lin(x, Q.fake_quant(w, 0, 8), Q.fake_quant(b, 0, 8))))
    assert res.error <= coarse
    assert res.rel_error < 0.1


def test_calibrated_bits_inside_windows():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(32, 16)) * 0.05, jnp.float32)
    b = jnp.asarray(rng.normal(size=(16,)) * 0.1, jnp.float32)
    x = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)
    o_ref = _lin(x, w, b)
    res = C.calibrate_linear_module(x, w, b, o_ref, _lin, tau=4)
    iw_lo, iw_hi = Q.search_window(w, 4)
    assert (8 - 1) - iw_hi <= res.n_w <= (8 - 1) - iw_lo


def test_add_module_only_searches_n_o():
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
    res = C.calibrate_add_module(a, b, a + b)
    assert res.n_w is None and res.n_b is None
    assert res.rel_error < 0.05


def test_sequential_threading_reduces_joint_error():
    """Two chained layers: calibrating layer 2 on layer 1's QUANTIZED output
    (the paper's joint dataflow) beats calibrating it on the clean output
    when the quantized model is evaluated end to end."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(32, 32)) * 0.2, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(32, 16)) * 0.2, jnp.float32)
    h_ref = jnp.maximum(x @ w1, 0)
    o_ref = h_ref @ w2

    r1 = C.calibrate_linear_module(
        x, w1, None, h_ref, lambda xx, ww, bb: jnp.maximum(xx @ ww, 0),
        out_unsigned=True)
    h_q = Q.fake_quant(jnp.maximum(x @ Q.fake_quant(w1, r1.n_w, 8), 0),
                       r1.n_o, 8, True)
    # joint: layer-2 calibration sees the quantized h
    r2_joint = C.calibrate_linear_module(
        h_q, w2, None, o_ref, lambda xx, ww, bb: xx @ ww)
    # ablation: layer-2 calibrated on the clean h (not dataflow-aware)
    r2_clean = C.calibrate_linear_module(
        h_ref, w2, None, o_ref, lambda xx, ww, bb: xx @ ww)

    def end_to_end(n_w2, n_o2):
        o = h_q @ Q.fake_quant(w2, n_w2, 8)
        return float(jnp.linalg.norm(o_ref - Q.fake_quant(o, n_o2, 8)))

    assert end_to_end(r2_joint.n_w, r2_joint.n_o) <= \
        end_to_end(r2_clean.n_w, r2_clean.n_o) + 1e-4


def test_report_histogram():
    rep = C.CalibrationReport()
    rep.add("a", C.CalibResult(n_w=8, n_b=7, n_o=3, error=0.1, fp_norm=1.0))
    rep.add("b", C.CalibResult(n_w=8, n_b=None, n_o=5, error=0.1, fp_norm=1.0))
    hist = rep.shift_histogram()
    assert hist[8] == 2 and hist[3] == 1 and hist[5] == 1
