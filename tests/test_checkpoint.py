"""Checkpointer: crash-safe commit, GC, restore, signature checks."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)},
            "step": jnp.asarray(seed, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    st = _state(3)
    ck.save(3, st, extra={"data_state": {"step": 3}}, blocking=True)
    restored, extra = ck.restore(jax.eval_shape(lambda: st))
    assert extra == {"data_state": {"step": 3}}
    assert np.allclose(restored["params"]["w"], st["params"]["w"])


def test_uncommitted_checkpoint_is_garbage_collected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(1), blocking=True)
    # simulate a crash mid-write: directory without COMMIT
    os.makedirs(tmp_path / "step_000000002")
    (tmp_path / "step_000000002" / "manifest.json").write_text("{}")
    assert ck.all_steps() == [1]
    assert not (tmp_path / "step_000000002").exists()
    assert ck.latest_step() == 1


def test_keep_n_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(s), blocking=True)
    assert ck.all_steps() == [3, 4]


def test_signature_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(1), blocking=True)
    wrong = {"params": {"w": jnp.zeros((4, 4))}, "step": jnp.zeros((), jnp.int32)}
    with pytest.raises(ValueError, match="signature"):
        ck.restore(jax.eval_shape(lambda: wrong))


def test_async_save_overlaps_then_waits(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _state(5))          # non-blocking
    ck.wait()
    assert ck.latest_step() == 5
