"""Unified-module construction (Fig. 1 fusion rules)."""
from repro.core.dataflow import OpKind as K, OpNode, build_plan, count_quant_ops


def test_case_b_conv_relu_fuses():
    plan = build_plan([
        OpNode("conv", K.LINEAR, ("in",), has_bias=True),
        OpNode("relu", K.RELU, ("conv",)),
    ])
    assert len(plan.modules) == 1
    m = plan.modules[0]
    assert m.case == "b" and m.out_unsigned and m.ops == ("conv", "relu")


def test_case_a_bare_conv():
    plan = build_plan([OpNode("conv", K.LINEAR, ("in",), has_bias=True)])
    assert plan.modules[0].case == "a"
    assert not plan.modules[0].out_unsigned


def test_case_c_residual_relu():
    plan = build_plan([
        OpNode("conv", K.LINEAR, ("in",)),
        OpNode("add", K.ADD, ("conv", "in")),
        OpNode("relu", K.RELU, ("add",)),
    ])
    add_mod = plan.module("um_add")
    assert add_mod.case == "c" and add_mod.out_unsigned


def test_case_d_residual_no_relu():
    plan = build_plan([
        OpNode("conv", K.LINEAR, ("in",)),
        OpNode("add", K.ADD, ("conv", "in")),
    ])
    assert plan.module("um_add").case == "d"
    assert not plan.module("um_add").out_unsigned


def test_norm_is_folded_not_a_quant_point():
    plan = build_plan([
        OpNode("bn", K.NORM, ("in",)),
        OpNode("conv", K.LINEAR, ("bn",)),
    ])
    assert len(plan.modules) == 1
    assert plan.modules[0].ops == ("conv",)


def test_joint_fewer_points_than_naive():
    """The paper's core hypothesis precondition: restructuring reduces the
    number of quantization operations."""
    nodes = [
        OpNode("c1", K.LINEAR, ("in",), has_bias=True),
        OpNode("r1", K.RELU, ("c1",)),
        OpNode("c2", K.LINEAR, ("r1",), has_bias=True),
        OpNode("add", K.ADD, ("c2", "in")),
        OpNode("r2", K.RELU, ("add",)),
    ]
    plan = build_plan(nodes)
    counts = count_quant_ops(plan)
    assert counts["joint_activation_points"] == 3
    assert counts["naive_activation_points"] == 5
    assert counts["saved"] == 2


def test_multi_consumer_relu_not_fused():
    # conv output feeds both a relu and an add: cannot fuse (b)
    nodes = [
        OpNode("conv", K.LINEAR, ("in",)),
        OpNode("relu", K.RELU, ("conv",)),
        OpNode("add", K.ADD, ("conv", "relu")),
    ]
    plan = build_plan(nodes)
    conv_mod = plan.module("um_conv")
    assert conv_mod.case == "a" and conv_mod.ops == ("conv",)


def test_dataflow_edges_thread_n_x():
    nodes = [
        OpNode("c1", K.LINEAR, ("in",)),
        OpNode("r1", K.RELU, ("c1",)),
        OpNode("c2", K.LINEAR, ("r1",)),
    ]
    plan = build_plan(nodes)
    assert plan.module("um_c2").inputs == ("um_c1",)
