"""int8 KV cache (Eq. 1 applied to the cache — beyond-paper feature)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.qmodel import QuantContext, QuantMode
from repro.models import model as M

CTX = QuantContext(mode=QuantMode.FP)


def test_int8_cache_matches_fp_cache():
    cfg = get_smoke_config("qwen3_1_7b").scaled(dtype="float32")
    cfg8 = dataclasses.replace(cfg, kv_cache_bits=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0,
                              cfg.vocab_size)
    pre = {"tokens": toks[:, :47]}
    _, cache_fp = M.prefill(params, pre, cfg, CTX, max_seq=48)
    _, cache_i8 = M.prefill(params, pre, cfg8, CTX, max_seq=48)
    assert cache_i8["kv"].k.dtype == jnp.int8
    l_fp, _ = M.decode_step(params, toks[:, 47:], cache_fp, jnp.asarray(47),
                            cfg, CTX)
    l_i8, _ = M.decode_step(params, toks[:, 47:], cache_i8, jnp.asarray(47),
                            cfg8, CTX)
    rel = float(jnp.linalg.norm(l_i8 - l_fp) / jnp.linalg.norm(l_fp))
    assert rel < 0.05, rel
    # top-1 agreement
    agree = float(jnp.mean((jnp.argmax(l_fp, -1) ==
                            jnp.argmax(l_i8, -1)).astype(jnp.float32)))
    assert agree >= 0.5


def test_int8_cache_halves_bytes():
    cfg = get_smoke_config("qwen3_1_7b")
    cfg8 = dataclasses.replace(cfg, kv_cache_bits=8)
    c_fp = jax.eval_shape(lambda: M.init_cache(cfg, 2, 64))
    c_i8 = jax.eval_shape(lambda: M.init_cache(cfg8, 2, 64))
    b_fp = sum(np.prod(l.shape) * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(c_fp))
    b_i8 = sum(np.prod(l.shape) * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(c_i8))
    assert b_i8 * 2 == b_fp
