"""Partition-rule unit tests (AbstractMesh — no multi-device env needed)."""
import jax
import jax.numpy as jnp
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.distributed import sharding as shd
from repro.launch import steps as S


def _abstract_mesh(sizes, names):
    """AbstractMesh across jax versions: 0.4.x takes ((name, size), ...);
    newer releases take (sizes, names)."""
    try:
        return AbstractMesh(tuple(sizes), tuple(names))
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


def _mesh(data=2, model=2):
    return _abstract_mesh((data, model), ("data", "model"))


def test_attention_and_mlp_rules():
    mesh = _mesh()
    cfg = get_smoke_config("qwen3_1_7b")
    abs_p = S.abstract_params(cfg)
    specs = shd.param_sharding_rules(abs_p, mesh, fsdp=False)
    blocks = specs["blocks"]
    assert blocks["attn"]["wq"] == P(None, None, "model")
    assert blocks["attn"]["wo"] == P(None, "model", None)
    assert blocks["mlp"]["w1"] == P(None, None, "model")
    assert blocks["mlp"]["w2"] == P(None, "model", None)
    assert specs["embed"] == P("model", None)
    assert specs["lm_head"] == P(None, "model")
    assert specs["ln_f"] in (P(), P(None))


def test_moe_expert_parallel_rules():
    mesh = _mesh()
    cfg = get_smoke_config("granite_moe_3b_a800m")
    specs = shd.param_sharding_rules(S.abstract_params(cfg), mesh, fsdp=False)
    assert specs["blocks"]["moe"]["w1"] == P(None, "model", None, None)
    assert specs["blocks"]["moe"]["router"] == P(None, None, None)


def test_fsdp_adds_data_axis_on_large_leaves():
    mesh = _mesh()
    big = jax.eval_shape(lambda: {"blocks": {"mlp": {
        "w1": jnp.zeros((16, 4096, 4096), jnp.bfloat16)}}})
    spec = shd.param_sharding_rules(big, mesh, fsdp=True)
    assert spec["blocks"]["mlp"]["w1"] == P(None, "data", "model")
    # small leaves stay unsharded on data
    small = jax.eval_shape(lambda: {"blocks": {"mlp": {
        "w1": jnp.zeros((2, 64, 64), jnp.bfloat16)}}})
    spec = shd.param_sharding_rules(small, mesh, fsdp=True)
    assert "data" not in tuple(spec["blocks"]["mlp"]["w1"])


def test_constrain_noop_outside_scope():
    x = jnp.zeros((4, 8))
    assert shd.constrain(x, ("batch", None)) is x
    assert shd.data_shards() == 1


def test_constrain_inside_scope_single_device():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with shd.activation_sharding(mesh):
        x = jnp.zeros((3, 5))
        y = shd.constrain(x, ("batch", "model"))
        assert y.shape == x.shape
        assert shd.data_shards() == 1


def test_cache_sharding_rules():
    mesh = _mesh()
    cfg = get_smoke_config("qwen3_1_7b")
    cache_abs = S.abstract_cache(cfg, batch=4, max_seq=128)
    specs = shd.cache_sharding_rules(cache_abs, mesh)
    k_spec = specs["kv"].k
    # batch 4 % 2 == 0; the composite-axis entry ("data",) is spec-equivalent
    assert k_spec[1] in ("data", ("data",))
    assert k_spec[3] in ("model", None)


def test_batch_sharding_composite_axis():
    multi = _abstract_mesh((2, 4, 4), ("pod", "data", "model"))
    assert shd.batch_sharding(multi, 2) == P(("pod", "data"), None)
