"""Partition-rule unit tests (AbstractMesh — no multi-device env needed)."""
import jax
import jax.numpy as jnp
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.distributed import sharding as shd
from repro.launch import steps as S


def _abstract_mesh(sizes, names):
    """AbstractMesh across jax versions: 0.4.x takes ((name, size), ...);
    newer releases take (sizes, names)."""
    try:
        return AbstractMesh(tuple(sizes), tuple(names))
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


def _mesh(data=2, model=2):
    return _abstract_mesh((data, model), ("data", "model"))


def test_attention_and_mlp_rules():
    mesh = _mesh()
    cfg = get_smoke_config("qwen3_1_7b")
    abs_p = S.abstract_params(cfg)
    specs = shd.param_sharding_rules(abs_p, mesh, fsdp=False)
    blocks = specs["blocks"]
    assert blocks["attn"]["wq"] == P(None, None, "model")
    assert blocks["attn"]["wo"] == P(None, "model", None)
    assert blocks["mlp"]["w1"] == P(None, None, "model")
    assert blocks["mlp"]["w2"] == P(None, "model", None)
    assert specs["embed"] == P("model", None)
    assert specs["lm_head"] == P(None, "model")
    assert specs["ln_f"] in (P(), P(None))


def test_moe_expert_parallel_rules():
    mesh = _mesh()
    cfg = get_smoke_config("granite_moe_3b_a800m")
    specs = shd.param_sharding_rules(S.abstract_params(cfg), mesh, fsdp=False)
    assert specs["blocks"]["moe"]["w1"] == P(None, "model", None, None)
    assert specs["blocks"]["moe"]["router"] == P(None, None, None)


def test_fsdp_adds_data_axis_on_large_leaves():
    mesh = _mesh()
    big = jax.eval_shape(lambda: {"blocks": {"mlp": {
        "w1": jnp.zeros((16, 4096, 4096), jnp.bfloat16)}}})
    spec = shd.param_sharding_rules(big, mesh, fsdp=True)
    assert spec["blocks"]["mlp"]["w1"] == P(None, "data", "model")
    # small leaves stay unsharded on data
    small = jax.eval_shape(lambda: {"blocks": {"mlp": {
        "w1": jnp.zeros((2, 64, 64), jnp.bfloat16)}}})
    spec = shd.param_sharding_rules(small, mesh, fsdp=True)
    assert "data" not in tuple(spec["blocks"]["mlp"]["w1"])


def test_constrain_noop_outside_scope():
    x = jnp.zeros((4, 8))
    assert shd.constrain(x, ("batch", None)) is x
    assert shd.data_shards() == 1


def test_constrain_inside_scope_single_device():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with shd.activation_sharding(mesh):
        x = jnp.zeros((3, 5))
        y = shd.constrain(x, ("batch", "model"))
        assert y.shape == x.shape
        assert shd.data_shards() == 1


def test_cache_sharding_rules():
    mesh = _mesh()
    cfg = get_smoke_config("qwen3_1_7b")
    cache_abs = S.abstract_cache(cfg, batch=4, max_seq=128)
    specs = shd.cache_sharding_rules(cache_abs, mesh)
    k_spec = specs["kv"].k
    # batch 4 % 2 == 0; the composite-axis entry ("data",) is spec-equivalent
    assert k_spec[1] in ("data", ("data",))
    assert k_spec[3] in ("model", None)


def test_batch_sharding_composite_axis():
    multi = _abstract_mesh((2, 4, 4), ("pod", "data", "model"))
    assert shd.batch_sharding(multi, 2) == P(("pod", "data"), None)


# ---------------------------------------------------------------------------
# divisibility / rank-fitting edge cases (the machinery every rule runs on)
# ---------------------------------------------------------------------------

def test_fit_rank_pads_and_truncates():
    # shorter spec than leaf rank: scan (layer-stack) axis gets None
    assert shd._fit_rank(P(None, "model"), 3) == [None, None, "model"]
    # longer spec than leaf rank: keep the TRAILING entries (the rule's
    # meaningful dims are rightmost)
    assert shd._fit_rank(P("model", None), 1) == [None]
    assert shd._fit_rank(P("model", None), 0) == []


def test_divisible_odd_head_counts():
    mesh = _mesh(data=2, model=2)
    # 7 heads on a 2-wide axis: not divisible
    assert not shd._divisible(["model", None], (7, 64), mesh)
    assert shd._divisible(["model", None], (8, 64), mesh)
    # composite-axis entry multiplies sizes: 4 needed
    assert not shd._divisible([("data", "model")], (6,), mesh)
    assert shd._divisible([("data", "model")], (8,), mesh)


def test_divisible_one_sized_mesh_axes():
    mesh = _mesh(data=1, model=1)
    # size-1 axes divide everything — odd dims included
    assert shd._divisible(["model", "data"], (7, 13), mesh)
    cfg = get_smoke_config("qwen3_1_7b")
    specs = shd.param_sharding_rules(S.abstract_params(cfg), mesh,
                                    fsdp=False)
    # rules still produce model-axis entries (sharding into 1 piece is
    # valid and keeps the spec stable across mesh sizes)
    assert specs["blocks"]["attn"]["wq"] == P(None, None, "model")


def test_spec_for_strips_non_dividing_axes():
    mesh = _mesh(data=2, model=16)
    # odd rows AND cols on a 16-wide model axis: every candidate fails,
    # the last-resort path strips the non-dividing entries instead of
    # crashing (granite's 40-expert case generalized)
    assert shd._spec_for("blocks/attn/wq", (24, 24), mesh) == P(None, None)
    # only the free dim fails -> the contract-dim candidate is used
    assert shd._spec_for("blocks/attn/wq", (64, 24), mesh) == \
        P("model", None)


def test_cache_rules_unmatched_leaves_fall_through():
    """Cache trees with leaves matching NO rule (not 5-dim KV, not 4-dim
    latent, not 'memory') must come back fully replicated, not crash."""
    mesh = _mesh()
    weird = jax.eval_shape(lambda: {
        "scalar_state": jnp.zeros((), jnp.float32),          # 0-dim
        "conv_state": jnp.zeros((2, 4, 3), jnp.float32),     # 3-dim, odd
        "flags": jnp.zeros((2, 7), jnp.int32),               # 2-dim, odd
    })
    specs = shd.cache_sharding_rules(weird, mesh)
    assert specs["scalar_state"] == P()
    assert specs["conv_state"] == P(None, None, None)
    assert specs["flags"] == P(None, None)


def test_flash_cache_rules_non_dividing_heads_fall_back():
    """attn_kernel='flash' head sharding only engages when kv_heads
    divides the model axis; otherwise the sequence-sharded chunked layout
    is kept (the flash resolver raises before this layout is used)."""
    mesh = _mesh(data=2, model=4)
    cfg = get_smoke_config("qwen3_1_7b")          # n_kv_heads = 2
    cache_abs = S.abstract_cache(cfg, batch=4, max_seq=128)
    specs = shd.cache_sharding_rules(cache_abs, mesh, attn_kernel="flash")
    k_spec = specs["kv"].k
    assert k_spec[3] is None and k_spec[2] == "model"
