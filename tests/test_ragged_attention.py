"""Unified ragged paged attention parity (DESIGN §12).

Grid: one MIXED work-list — a prefill chunk mid-prompt, a decode row, a
speculative tail, and a from-scratch prefill chunk packed into a single
flattened stream — x GQA {1, 4} x KV {int8, bf16} x mesh {1x1, 2x2,
4x1}, checked against (a) the fp32 gather oracle
(``kernels.ref.ragged_attention_ref``), (b) the dense chunked-attention
oracle per item, and (c) the EXISTING per-shape paged kernels serving
each item at its own legacy shape.  MXU-aligned builds run the Pallas
body in interpret mode on CPU CI; the engine-shape build exercises the
gather fallback.  Plus the engine-level regression that the ragged path
dispatches strictly less padding than the bucketed per-shape path on a
mixed workload.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qscheme import dequant, quant
from repro.kernels import ops
from repro.kernels.ref import ragged_attention_ref
from repro.models.attention import _repeat_kv, chunked_attention

NKV = 4
SMAX, DK = 256, 128

# the mixed step: (q_len, kv_len) per sequence — a 32-token prefill
# chunk continuing 128 resident rows, a decode row at context 131, a
# 5-token speculative tail rooted at context 32, and a fresh 16-token
# prefill opening a sequence
ITEMS = ((32, 160), (1, 131), (5, 37), (16, 16))


def _build_mixed(seed, kvh, groups, kv, *, bs=128, smax=SMAX, dk=DK,
                 items=ITEMS):
    """Pack the ITEMS work-list into one stream over a shuffled pool.

    Returns (q_stream, k_pool, v_pool, bt, q_start, q_len, kv_len, nkv,
    qf, kd, vd): qf/kd/vd are the fp32 dense per-sequence views the
    oracle consumes (kd/vd dequantized, length smax per sequence)."""
    rng = np.random.default_rng(seed)
    h = kvh * groups
    nbmax = smax // bs
    ns = len(items)
    t = sum(q for q, _ in items)
    q = jnp.asarray(rng.normal(size=(t, h, dk)), jnp.float32)
    kf = jnp.asarray(rng.normal(size=(ns, smax, kvh, dk)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(ns, smax, kvh, dk)), jnp.float32)
    if kv == "int8":
        kc, vc = quant(kf, NKV, 8), quant(vf, NKV, 8)
        kd, vd = dequant(kc, NKV), dequant(vc, NKV)
        nkv = NKV
    else:
        kc, vc = kf.astype(jnp.bfloat16), vf.astype(jnp.bfloat16)
        kd, vd = kc.astype(jnp.float32), vc.astype(jnp.float32)
        q = q.astype(jnp.bfloat16)
        nkv = None
    nb = 1 + ns * nbmax
    bt = rng.permutation(np.arange(1, nb)).reshape(ns, nbmax).astype(np.int32)
    kp = np.zeros((nb, bs, kvh, dk), np.asarray(kc).dtype)
    vp = np.zeros_like(kp)
    for s in range(ns):
        for i in range(nbmax):
            kp[bt[s, i]] = np.asarray(kc[s, i * bs:(i + 1) * bs])
            vp[bt[s, i]] = np.asarray(vc[s, i * bs:(i + 1) * bs])
    q_len = np.asarray([ql for ql, _ in items], np.int32)
    kv_len = np.asarray([kl for _, kl in items], np.int32)
    q_start = np.concatenate([[0], np.cumsum(q_len)[:-1]]).astype(np.int32)
    return (q, jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(bt),
            jnp.asarray(q_start), jnp.asarray(q_len), jnp.asarray(kv_len),
            nkv, q.astype(jnp.float32), kd, vd)


def _tol(kv):
    return dict(atol=2e-2, rtol=2e-2) if kv == "bf16" else \
        dict(atol=1e-4, rtol=1e-4)


def _check_vs_dense(out, qf, kd, vd, groups, items, kv):
    """Every work-list item against the dense chunked-attention oracle
    at its own (q_len, kv_len) — the dataflow the ragged kernel fuses."""
    off = 0
    for s, (ql, kl) in enumerate(items):
        ref = chunked_attention(
            qf[None, off:off + ql], _repeat_kv(kd[s:s + 1, :kl], groups),
            _repeat_kv(vd[s:s + 1, :kl], groups), causal=True,
            q_offset=jnp.asarray(kl - ql, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(out[off:off + ql], np.float32),
            np.asarray(ref[0], np.float32),
            err_msg=f"item {s} (q_len={ql}, kv_len={kl})", **_tol(kv))
        off += ql


@pytest.mark.parametrize("kv", ["int8", "bf16"])
@pytest.mark.parametrize("groups", [1, 4])
def test_ragged_mixed_parity(groups, kv):
    """One pallas_call (interpret on CPU) serves the whole mixed step:
    matches both the gather oracle and the dense oracle per item."""
    (q, kp, vp, bt, qs, ql, kl, nkv, qf, kd, vd) = \
        _build_mixed(3, 2, groups, kv)
    out = ops.ragged_attention(q, kp, vp, bt, qs, ql, kl,
                               kv_frac_bits=nkv, tq_max=32)
    oracle = ragged_attention_ref(qf, kp, vp, bt, qs, ql, kl,
                                  kv_frac_bits=nkv)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(oracle, np.float32), **_tol(kv))
    _check_vs_dense(out, qf, kd, vd, groups, ITEMS, kv)


@pytest.mark.parametrize("kv", ["int8", "bf16"])
def test_ragged_matches_per_shape_kernels(kv):
    """The unified call reproduces what the RETIRED per-shape dispatches
    computed: each item re-served at its legacy shape through
    ``ops.paged_attention`` (fused decode kernel / chunk reference) must
    match its rows of the ragged output."""
    groups = 2
    (q, kp, vp, bt, qs, ql, kl, nkv, qf, kd, vd) = \
        _build_mixed(7, 2, groups, kv)
    out = ops.ragged_attention(q, kp, vp, bt, qs, ql, kl,
                               kv_frac_bits=nkv, tq_max=32)
    off = 0
    for s, (ql_i, kl_i) in enumerate(ITEMS):
        legacy = ops.paged_attention(
            q[None, off:off + ql_i], kp, vp, bt[s:s + 1],
            (kl_i - ql_i + jnp.arange(ql_i, dtype=jnp.int32))[None],
            kv_frac_bits=nkv)
        np.testing.assert_allclose(
            np.asarray(out[off:off + ql_i], np.float32),
            np.asarray(legacy[0], np.float32),
            err_msg=f"item {s} (q_len={ql_i}, kv_len={kl_i})", **_tol(kv))
        off += ql_i


@pytest.mark.parametrize("kv", ["int8", "bf16"])
def test_ragged_fallback_small_dims(kv):
    """Engine smoke shapes (block 16, head_dim 16) refuse the kernel and
    take the gather reference — same contract, same mixed step."""
    items = ((8, 40), (1, 33), (3, 11), (4, 4))
    (q, kp, vp, bt, qs, ql, kl, nkv, qf, kd, vd) = \
        _build_mixed(5, 2, 2, kv, bs=16, smax=64, dk=16, items=items)
    out = ops.ragged_attention(q, kp, vp, bt, qs, ql, kl, kv_frac_bits=nkv)
    _check_vs_dense(out, qf, kd, vd, 2, items, kv)


@pytest.mark.parametrize("mesh_shape", [(2, 2), (1, 4)])
@pytest.mark.parametrize("groups", [1, 4])
def test_ragged_sharded_parity(groups, mesh_shape):
    """4-device shard_map case (DESIGN §8 composes unchanged): pool and
    stream head-sharded over 'model', descriptors replicated — must match
    the single-device dense oracle."""
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices (tests/conftest.py forces them)")
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    (q, kp, vp, bt, qs, ql, kl, nkv, qf, kd, vd) = \
        _build_mixed(9, 4, groups, "int8")
    out = ops.ragged_attention(q, kp, vp, bt, qs, ql, kl,
                               kv_frac_bits=nkv, tq_max=32, mesh=mesh)
    _check_vs_dense(out, qf, kd, vd, groups, ITEMS, "int8")


def test_ragged_non_dividing_heads_raise():
    """No-silent-fallback contract: a tensor axis that would split a GQA
    group is refused at the ops level, like every other flash kernel."""
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    (q, kp, vp, bt, qs, ql, kl, nkv, *_rest) = _build_mixed(11, 2, 1, "int8")
    with pytest.raises(NotImplementedError, match=r"KV head count \(2\)"):
        ops.ragged_attention(q, kp, vp, bt, qs, ql, kl,
                             kv_frac_bits=nkv, mesh=mesh)


def test_ragged_padding_rows_zero():
    """Stream rows covered by no descriptor are EXACTLY zero — on the
    kernel path they are never written (the wrapper pins them), on the
    gather path the all-masked softmax NaN is pinned the same way."""
    for bs, smax, dk in ((128, 256, 128), (16, 64, 16)):
        (q, kp, vp, bt, *_rest) = _build_mixed(
            13, 2, 2, "int8", bs=bs, smax=smax, dk=dk,
            items=((8, 16), (8, 16)))
        # 16 stream rows, but the descriptors claim only 9 of them
        qs = jnp.asarray([0, 8], jnp.int32)
        ql = jnp.asarray([8, 1], jnp.int32)
        kl = jnp.asarray([16, 9], jnp.int32)
        out = ops.ragged_attention(q, kp, vp, bt, qs, ql, kl,
                                   kv_frac_bits=NKV, tq_max=8)
        pad = np.asarray(out)[9:]
        assert np.all(pad == 0), "unclaimed stream rows must be zero"
        assert np.all(np.isfinite(np.asarray(out)))


def test_ragged_int8_requires_frac_bits():
    (q, kp, vp, bt, qs, ql, kl, *_rest) = _build_mixed(15, 2, 1, "int8")
    with pytest.raises(ValueError, match="kv_frac_bits"):
        ops.ragged_attention(q, kp, vp, bt, qs, ql, kl)
