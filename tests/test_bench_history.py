"""Bench-history ledger + noise-aware regression detection (ISSUE 9).

Pure python (no jax): fingerprint stability over workload-defining
fields only, dotted-path extraction that tolerates pre-obs snapshots,
JSONL append/load round-trips, and the regression verdicts — best-of-N
baselines, per-metric direction + relative tolerance, zero-tolerance
parity metrics, and the trivially-passing no-matching-baseline case.
"""
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.bench_history import (HISTORY_SCHEMA, TRACKED,  # noqa: E402
                                      append_entry, entry_of, extract,
                                      fingerprint_of, load_history,
                                      regress)


def bench(**over):
    b = {
        "backend": "cpu",
        "workload": {"n_requests": 16, "rate_req_s": 1000.0, "seed": 0},
        "continuous": {"tokens_per_s": 800.0},
        "speedup_tokens_per_s": 1.2,
        "decode_steps": {"continuous": 57, "static": 120},
        "w8a8": {"agreement_int_ref": 1.0,
                 "workload": {"n_requests": 16, "seed": 0},
                 "tokens_per_s_best": {"w8a8": 500.0}},
        "flight_recorder": {"decisions": 88, "replay_diff_lines": 0,
                            "workload": {"n_requests": 12, "seed": 0}},
        "slo": {"overload": {"alerts_fired": 2},
                "healthy": {"alerts_fired": 0},
                "workload": {"n_requests": 16, "seed": 0}},
    }
    b.update(over)
    return b


def test_fingerprint_hashes_workloads_not_measurements():
    a = bench()
    assert fingerprint_of(a) == fingerprint_of(bench())
    # measurements don't move it
    faster = bench(continuous={"tokens_per_s": 9999.0},
                   speedup_tokens_per_s=9.0)
    assert fingerprint_of(faster) == fingerprint_of(a)
    # workload-defining fields do
    assert fingerprint_of(bench(backend="gpu")) != fingerprint_of(a)
    moved = bench(workload={"n_requests": 32, "rate_req_s": 1000.0,
                            "seed": 0})
    assert fingerprint_of(moved) != fingerprint_of(a)
    w8 = bench()
    w8["w8a8"] = dict(w8["w8a8"], workload={"n_requests": 8, "seed": 0})
    assert fingerprint_of(w8) != fingerprint_of(a)


def test_extract_tolerates_missing_sections():
    m = extract(bench())
    assert m["continuous.tokens_per_s"] == 800.0
    assert m["flight_recorder.replay_diff_lines"] == 0.0
    assert m["slo.overload.alerts_fired"] == 2.0
    # a pre-obs snapshot still extracts its common subset
    old = {"backend": "cpu", "continuous": {"tokens_per_s": 700.0},
           "speedup_tokens_per_s": 1.1}
    m_old = extract(old)
    assert set(m_old) == {"continuous.tokens_per_s",
                          "speedup_tokens_per_s"}
    # non-numeric / non-finite values are skipped, not crashed on
    weird = bench(continuous={"tokens_per_s": float("nan")},
                  speedup_tokens_per_s="fast")
    bad = extract(weird)
    assert "continuous.tokens_per_s" not in bad
    assert "speedup_tokens_per_s" not in bad
    # every tracked path is unique
    paths = [t.path for t in TRACKED]
    assert len(paths) == len(set(paths))


def test_ledger_round_trip(tmp_path):
    path = str(tmp_path / "hist.jsonl")
    assert load_history(path) == []            # missing file is empty
    e1 = entry_of(bench(), run={"seed": 0})
    append_entry(path, e1)
    append_entry(path, entry_of(bench(continuous={"tokens_per_s":
                                                  850.0})))
    hist = load_history(path)
    assert len(hist) == 2
    assert hist[0] == e1
    assert hist[0]["schema"] == HISTORY_SCHEMA
    assert hist[1]["metrics"]["continuous.tokens_per_s"] == 850.0
    # schema gate: a future-format line fails loudly, not silently
    with open(path, "a") as f:
        f.write('{"schema": 99}\n')
    with pytest.raises(ValueError, match="schema"):
        load_history(path)


def test_regress_verdicts(tmp_path):
    history = [entry_of(bench()),
               entry_of(bench(continuous={"tokens_per_s": 850.0}))]
    # identical run passes against itself (best-of-N baseline = 850)
    assert regress(bench(), history) == []
    # within tolerance: tokens/s has rel_tol 0.60 -> floor 340
    assert regress(bench(continuous={"tokens_per_s": 400.0}),
                   history) == []
    # beyond tolerance fails, and the message names the metric
    fails = regress(bench(continuous={"tokens_per_s": 200.0}), history)
    assert len(fails) == 1
    assert fails[0].startswith("continuous.tokens_per_s")
    # zero-tolerance parity metric: ANY drop fails
    fails = regress(bench(w8a8={"agreement_int_ref": 0.999,
                                "workload": {"n_requests": 16,
                                             "seed": 0},
                                "tokens_per_s_best": {"w8a8": 500.0}}),
                    history)
    assert any(f.startswith("w8a8.agreement_int_ref") for f in fails)
    # lower-is-better direction: replay diff lines appearing is a fail
    degraded = bench()
    degraded["flight_recorder"] = dict(degraded["flight_recorder"],
                                       replay_diff_lines=4)
    fails = regress(degraded, history)
    assert any(f.startswith("flight_recorder.replay_diff_lines")
               for f in fails)
    # IMPROVEMENTS never fail
    assert regress(bench(continuous={"tokens_per_s": 2000.0}),
                   history) == []


def test_regress_without_matching_baseline_passes_with_warning(capsys):
    history = [entry_of(bench(backend="gpu"))]
    assert regress(bench(), history) == []
    assert "no history entry matches" in capsys.readouterr().out
    assert regress(bench(), []) == []
