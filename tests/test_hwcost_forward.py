"""Full-forward Table-5 accounting for W8A8 serving (DESIGN §13).

Three layers of exactness:
  * ``forward_quant_ops_per_token`` equals an independent per-module
    enumeration of the transformer forward's quant points;
  * a W8A8 engine run counts EXACTLY fed_tokens x per-token ops — and
    exactly zero with W8A8 off (the forward keys must not bleed into the
    KV-path counters, which tests pin separately);
  * the forward counters reconcile against the KV counters under prefix
    sharing and speculation: every increment site feeds both families
    with the same token multiplier, so the cross-products are equal.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import hwcost
from repro.core.lm_calibrate import calibrate_lm
from repro.core.qmodel import QuantContext, QuantMode, quantize_params
from repro.models import model as M
from repro.serving import Request, ServingEngine

SCALE = dict(dtype="float32", n_layers=2, d_model=64, n_heads=4,
             n_kv_heads=2, d_ff=128, head_dim=16)


def _cfg(**kw):
    cfg = get_smoke_config("qwen3_1_7b").scaled(**SCALE)
    return dataclasses.replace(cfg, kv_cache_bits=8, **kw)


@pytest.mark.parametrize("scale", [
    SCALE,
    dict(dtype="float32", n_layers=3, d_model=96, n_heads=6,
         n_kv_heads=3, d_ff=160, head_dim=32),
])
def test_per_token_formula_matches_module_enumeration(scale):
    """Independent re-derivation: walk the forward module by module and
    sum (input quant elems + output requant elems) per token."""
    cfg = get_smoke_config("qwen3_1_7b").scaled(**scale)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    modules = []                       # (in_features, out_features)
    for _ in range(cfg.n_layers):
        modules += [(d, cfg.n_heads * hd),        # attn/wq
                    (d, cfg.n_kv_heads * hd),     # attn/wk
                    (d, cfg.n_kv_heads * hd),     # attn/wv
                    (cfg.n_heads * hd, d),        # attn/wo
                    (d, cfg.d_ff),                # mlp/w1
                    (d, cfg.d_ff),                # mlp/w3
                    (cfg.d_ff, d)]                # mlp/w2
    modules += [(d, cfg.vocab_padded)]            # lm_head
    want = sum(i + o for i, o in modules)
    assert hwcost.forward_quant_ops_per_token(cfg) == want


@pytest.fixture(scope="module")
def cal():
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(2, 32)), jnp.int32)}
    ctx_cal, _ = calibrate_lm(
        lambda p, b, c: M.forward(p, b, cfg, c), params, batch)
    ctx = dataclasses.replace(ctx_cal, mode=QuantMode.INT)
    return dict(cfg=cfg, params=params, ctx=ctx,
                qp=quantize_params(params, ctx))


def _reqs(rng, n, vocab, *, prefix=0):
    pre = rng.integers(0, vocab, size=prefix).astype(np.int32)
    out = []
    for i in range(n):
        tail = rng.integers(0, vocab, size=int(rng.integers(5, 12))
                            ).astype(np.int32)
        out.append(Request(
            rid=i, prompt=np.concatenate([pre, tail]) if prefix else tail,
            max_new_tokens=int(rng.integers(3, 7))))
    return out


def _run(cfg, params, ctx, reqs, **kw):
    eng = ServingEngine(cfg, params, ctx, n_slots=2, block_size=8,
                        max_model_len=48, chunk=8, **kw)
    rep = eng.run(reqs)
    assert rep["completed"] == len(reqs)
    return eng, rep


@pytest.mark.parametrize("w8a8", [True, False])
def test_engine_counts_exactly_fed_tokens(cal, w8a8):
    """Greedy decode, unique prompts, no prefix cache: a request of
    prompt P generating G tokens feeds P + G - 1 tokens through the
    forward (the prefill's last position samples token 1), and the W8A8
    counter is exactly that total times the per-token formula.  With
    W8A8 off, every forward key reports zero."""
    cfg = _cfg(matmul_kernel="int8") if w8a8 else _cfg()
    params = cal["qp"] if w8a8 else cal["params"]
    ctx = cal["ctx"] if w8a8 else QuantContext(mode=QuantMode.FP)
    reqs = _reqs(np.random.default_rng(2), 5, cfg.vocab_size)
    eng, rep = _run(cfg, params, ctx, reqs, prefix_cache=False)
    fed = sum(len(r.prompt) + r.max_new_tokens - 1 for r in reqs)
    hw = rep["hwcost"]
    per_tok = hwcost.forward_quant_ops_per_token(cfg)
    if w8a8:
        assert hw["w8a8"] is True
        assert hw["forward_quant_ops_per_token"] == per_tok
        assert hw["requant_ops_forward"] == fed * per_tok
        assert hw["requant_ops_forward_avoided_prefix_cache"] == 0
        assert hw["requant_ops_forward_wasted_speculation"] == 0
        assert hw["energy_uj_forward_bit_shift"] == pytest.approx(
            hwcost.estimate("bit_shifting", fed * per_tok).energy_uj)
        # Table 5's gap, now full-forward: shift-based requant vs the
        # per-tensor scaling-factor baseline on the same op count
        assert hw["energy_uj_forward_if_scaling_factor"] > \
            hw["energy_uj_forward_bit_shift"]
    else:
        assert hw["w8a8"] is False
        assert hw["forward_quant_ops_per_token"] == 0
        assert hw["requant_ops_forward"] == 0
        assert hw["energy_uj_forward_bit_shift"] == 0.0
        # KV-path accounting still runs on the dense engine
        assert hw["requant_ops_performed"] > 0


@pytest.mark.parametrize("scenario", ["prefix", "spec"])
def test_forward_reconciles_with_kv_counters(cal, scenario):
    """Both counter families see the same fed/avoided/wasted token
    streams, so forward * kv_per_token == kv * forward_per_token holds
    EXACTLY — under prefix-cache admission skips and speculative
    rollback alike.  A drifting increment site breaks the product."""
    cfg = _cfg(matmul_kernel="int8")
    rng = np.random.default_rng(3)
    kw = dict(spec_k=2) if scenario == "spec" else {}
    reqs = _reqs(rng, 5, cfg.vocab_size,
                 prefix=16 if scenario == "prefix" else 0)
    eng, rep = _run(cfg, cal["qp"], cal["ctx"], reqs, **kw)
    kv_per, fwd_per = eng._elems_per_token, eng._fwd_elems_per_token
    assert fwd_per > 0 and kv_per > 0
    assert eng.requant_ops_forward * kv_per == \
        eng.requant_ops_performed * fwd_per
    assert eng.requant_ops_forward_avoided_cache * kv_per == \
        eng.requant_ops_avoided_cache * fwd_per
    assert eng.requant_ops_forward_wasted_spec * kv_per == \
        eng.requant_ops_wasted_spec * fwd_per
    if scenario == "prefix":
        assert eng.requant_ops_forward_avoided_cache > 0
    if scenario == "spec":
        assert rep["spec_steps"] > 0
