"""Recurrent/hybrid serving on the fixed-slab substrate (DESIGN §16).

CI `serving` gates:

* ENGINE PARITY — RWKV6 and zamba2 (hybrid) continuous batching from the
  slab substrate emits token-for-token the static-batch dense fp32
  oracle's greedy output.  The workload queues a third request behind
  two slots so a recycled slab is exercised: a slab handed back LIFO
  still holds its previous owner's FINAL state, and a missed
  zero-on-admission only diverges several decode tokens in (the decay
  has to amplify the stale codes) — exactly the regression this test
  pinned down.
* PREEMPTION SNAPSHOT — on the pure-recurrent substrate preemption
  snapshots the O(1) state instead of §9 recompute; a mid-decode
  eviction + resume must still match the oracle exactly.
* SCHEDULER GUARDS — ``grow_for_spec`` and engine COW raise
  ``BlockPoolError`` with scheduling context on fixed-state sequences
  (satellite: the §11/§10 verbs are structurally impossible here).
* FRIENDLY ERRORS — ``spec_k``/``prefix_cache=True`` on a recurrent
  arch fail at engine CONSTRUCTION with an actionable message.
* FLIGHT RECORDER — slab alloc/free land in the §15 decision stream and
  a zamba2 capture→replay reproduces tokens with a ZERO-line decision
  diff.
* SCHEMA — the report passes the golden schema with the slab section on
  and the KV sections off.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.qmodel import QuantContext, QuantMode
from repro.models import model as M
from repro.obs.replay import capture_workload, replay_workload
from repro.obs.schema import diff_schema, schema_of
from repro.serving import (BlockPoolError, Request, RequestState,
                           ServingEngine)

CTX = QuantContext(mode=QuantMode.FP)
ARCHS = ["rwkv6_3b", "zamba2_2_7b"]


def _cfg(arch, **kw):
    cfg = get_smoke_config(arch)
    return dataclasses.replace(cfg, dtype="float32", **kw)


def _dense_oracle(cfg, params, prompt: np.ndarray, gen: int) -> list:
    p_len = len(prompt)
    logits, cache = M.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                              cfg, CTX, max_seq=p_len + gen)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [int(tok[0, 0])]
    for i in range(gen - 1):
        l, cache = M.decode_step(params, tok, cache,
                                 jnp.asarray(p_len + i, jnp.int32), cfg, CTX)
        tok = jnp.argmax(l, -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    return out


def _check_vs_oracle(cfg, params, reqs, outputs):
    for r in reqs:
        oracle = _dense_oracle(cfg, params, r.prompt, r.max_new_tokens)
        got = outputs[r.rid].tolist()
        assert got == oracle[:len(got)] and len(got) == r.max_new_tokens, \
            f"req {r.rid}: engine {got} vs oracle {oracle}"


def _workload(rng, n, vocab, *, arrivals=True):
    t, reqs = 0.0, []
    for i in range(n):
        t += float(rng.exponential(0.02)) if arrivals else 0.0
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, vocab, size=int(
                rng.integers(6, 20))).astype(np.int32),
            max_new_tokens=int(rng.integers(4, 9)), arrival=t))
    return reqs


def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("chunk", 8)
    kw.setdefault("max_model_len", 64)
    kw.setdefault("block_size", 4)
    return ServingEngine(cfg, params, CTX, **kw)


# -- token parity -----------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_engine_matches_dense_oracle_with_slab_reuse(arch):
    """3 requests through 2 slots: the queued request lands on a
    recycled slab and must still match the oracle token-for-token."""
    cfg = _cfg(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _workload(np.random.default_rng(1), 3, cfg.vocab_size)
    eng = _engine(cfg, params)
    rep = eng.run(reqs)
    assert rep["completed"] == len(reqs)
    eng.state_pool.check_invariants()
    assert eng.state_pool.n_live == 0
    assert rep["substrate"] == ("hybrid" if arch.startswith("zamba")
                                else "recurrent")
    assert eng.state_pool.stats.allocs == len(reqs)    # one slab each
    if eng.pool is not None:                           # hybrid KV half
        eng.pool.check_invariants()
        assert eng.pool.n_live == 0
    _check_vs_oracle(cfg, params, reqs, eng.outputs())
    # context-free state requant: the headline gauge is populated
    assert rep["hwcost"]["requant_ops_per_token"] > 0
    assert rep["state_pool"]["state_quant_ops_per_step"] > 0


def test_int8_slabs_requantize_and_stay_close_to_oracle():
    """state_bits=8 runs the whole int8 slab path (codes + per-slab po2
    grid); greedy tokens track the fp32 oracle on the smoke model and
    the requant energy accounting flips from 'avoided' to 'performed'."""
    cfg = _cfg("rwkv6_3b", state_bits=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _workload(np.random.default_rng(2), 3, cfg.vocab_size)
    eng = _engine(cfg, params)
    rep = eng.run(reqs)
    assert rep["completed"] == len(reqs)
    assert rep["state_pool"]["scale_exp"] == cfg.state_frac_bits
    # int8 slabs EXECUTE the per-step state requant ops
    assert rep["hwcost"]["requant_ops_performed"] >= \
        eng.recurrent_steps * rep["state_pool"]["state_quant_ops_per_step"]
    # fp32 slabs would count the same ops as avoided; the per-token
    # headline is storage-mode-independent by construction
    cfg32 = _cfg("rwkv6_3b")
    eng32 = _engine(cfg32, M.init_params(cfg32, jax.random.PRNGKey(0)))
    rep32 = eng32.run(_workload(np.random.default_rng(2), 3,
                                cfg32.vocab_size))
    assert rep32["hwcost"]["requant_ops_per_token"] == \
        rep["hwcost"]["requant_ops_per_token"]


# -- preemption snapshot ----------------------------------------------------

def test_preempt_snapshot_resume_matches_oracle():
    """Mid-decode eviction on the pure-recurrent substrate snapshots the
    slab (NOT §9 recompute) and the resumed request finishes exactly on
    the oracle's tokens."""
    cfg = _cfg("rwkv6_3b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    reqs = [Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=19).astype(np.int32), max_new_tokens=10)]
    eng = _engine(cfg, params)
    for r in reqs:
        eng.submit(r)
    preempted = False
    for _ in range(200):
        if eng.sched.idle:
            break
        req = reqs[0]
        if (not preempted and req.state is RequestState.DECODE
                and len(req.generated) >= 4):
            eng.sched.preempt(req, eng._now())
            assert req.snapshot is not None, "recurrent preemption " \
                "must snapshot the slab, not schedule a recompute"
            preempted = True
        eng.step()
    assert preempted and eng.sched.idle
    assert eng.state_pool.stats.seq_evictions == 1
    _check_vs_oracle(cfg, params, reqs, eng.outputs())


# -- scheduler guards (satellite 1) -----------------------------------------

def test_grow_for_spec_and_cow_raise_on_fixed_state():
    cfg = _cfg("rwkv6_3b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = _engine(cfg, params)
    req = Request(rid=0, prompt=np.arange(8, dtype=np.int32),
                  max_new_tokens=4)
    with pytest.raises(BlockPoolError, match="fixed-size recurrent"):
        eng.sched.grow_for_spec(req, 0.0, 3)
    with pytest.raises(BlockPoolError, match="never shares a block"):
        eng._cow_for_range(req, 0, 8)
    with pytest.raises(BlockPoolError, match="no prefix cache"):
        eng.sched.cow_for_prefill(req, 0, 0.0)
    with pytest.raises(BlockPoolError, match="cannot extend"):
        eng.state_pool.extend(0, 32)


# -- friendly construction errors (satellite 2) -----------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_spec_and_prefix_cache_rejected_at_construction(arch):
    cfg = _cfg(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="speculative decoding"):
        _engine(cfg, params, spec_k=2)
    with pytest.raises(ValueError, match="not an addressable token"):
        _engine(cfg, params, prefix_cache=True)


# -- flight recorder (satellite 6) ------------------------------------------

def test_zamba2_capture_replay_zero_decision_diff():
    """Hybrid capture→replay: identical tokens, EMPTY decision diff, and
    the slab lifecycle is part of the recorded decision stream."""
    cfg = _cfg("zamba2_2_7b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _workload(np.random.default_rng(4), 4, cfg.vocab_size)
    eng = _engine(cfg, params, record=True)
    eng.run(reqs)
    rec = capture_workload(eng, reqs)
    names = {n for n, _ in rec.decisions}
    assert {"pool.slab_alloc", "pool.slab_free"} <= names
    assert {"pool.alloc", "pool.free"} <= names        # hybrid KV half
    assert rec.meta["recurrent_steps"] == eng.recurrent_steps > 0
    # recurrent records carry the substrate in the fingerprint input
    assert rec.engine["substrate"] == "hybrid"
    assert rec.engine["num_slabs"] == eng.state_pool.num_slabs

    fresh = _engine(cfg, M.init_params(cfg, jax.random.PRNGKey(0)),
                    record=True)
    res = replay_workload(rec, fresh, strict_fingerprint=True)
    assert res.token_identical and res.decision_diff == []
    assert res.ok and res.fingerprint_match


def test_rwkv6_snapshot_preemption_is_replay_deterministic():
    """An undersized slab pool forces snapshot preemption during the
    run; the capture must still replay with a zero-line diff."""
    cfg = _cfg("rwkv6_3b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _workload(np.random.default_rng(5), 4, cfg.vocab_size)
    eng = _engine(cfg, params, record=True)
    eng.run(reqs)
    rec = capture_workload(eng, reqs)
    fresh = _engine(cfg, M.init_params(cfg, jax.random.PRNGKey(0)),
                    record=True)
    res = replay_workload(rec, fresh, strict_fingerprint=True)
    assert res.ok


# -- schema (satellite 3/5) -------------------------------------------------

def test_recurrent_report_passes_golden_schema():
    cfg = _cfg("rwkv6_3b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = _engine(cfg, params, trace=True)
    eng.run(_workload(np.random.default_rng(6), 2, cfg.vocab_size))
    errs = diff_schema(schema_of(eng.metrics), spec=False, cache=False,
                       kv=False, slab=True)
    assert errs == [], "\n".join(errs)
    eng.metrics.check_aliases()
    rep = eng.report()
    assert rep["pool"] is None and rep["prefix_cache"] is None
    assert rep["state_pool"]["num_slabs"] == eng.state_pool.num_slabs
