"""Property-based round-trip invariants for the Eq. 1 po2 scheme.

Runs under real hypothesis when installed, else the deterministic sampled
fallback in ``_hyp_stub`` (seeded rng — failures reproduce).  These lock
in permanently:

* quantize -> dequantize IDEMPOTENCE: the po2 grid is a fixed point of
  Eq. 1, so a second pass through the quantizer changes nothing;
* power-of-two scale MONOTONICITY: the 2^-(n+1) grid is a superset of the
  2^-n grid (grids are nested), so reconstruction error is pointwise
  non-increasing in the fractional bit — the property Algorithm 1's
  window search relies on;
* bias-shift SIGN: ``shift_requant`` with a negative shift is an exact
  LEFT shift (and matches the float round-half-away reference for either
  sign), and ``ops.int8_matmul`` agrees bit-exactly with ``int_linear``
  when ``bias_shift`` < 0 — the PR 1 negative-shift kernel regression,
  held permanently by property rather than one fixed example.
"""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container lacks hypothesis
    from _hyp_stub import given, settings, st

from repro.core import qscheme as Q
from repro.core.integer_ops import LinearQuantSpec, int_linear
from repro.kernels import ops


def _x(seed, size=512):
    return jnp.asarray(np.random.default_rng(seed).normal(size=size) * 4.0,
                       jnp.float32)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(-3, 7), bits=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 2**16))
def test_quantize_dequantize_idempotent(n, bits, seed):
    x = _x(seed)
    fq1 = Q.fake_quant(x, n, bits)
    # float fixed point: re-quantizing the reconstruction is the identity
    assert jnp.array_equal(Q.fake_quant(fq1, n, bits), fq1)
    # integer fixed point: codes survive a dequant -> quant round trip
    c1 = Q.quant(x, n, bits)
    assert jnp.array_equal(Q.quant(Q.dequant(c1, n), n, bits), c1)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(0, 5), seed=st.integers(0, 2**16))
def test_scale_monotonicity(n, seed):
    # inputs inside the clip-free range of BOTH grids: |x| < 1, so
    # |round(x * 2^(n+1))| <= 2^6 < 127 for n <= 5 — error differences are
    # purely rounding, never clipping
    x = jnp.asarray(np.random.default_rng(seed).uniform(-1, 1, 512),
                    jnp.float32)
    assert Q.QuantParams(n + 1).scale == Q.QuantParams(n).scale / 2
    err_coarse = jnp.abs(Q.fake_quant(x, n, 8) - x)
    err_fine = jnp.abs(Q.fake_quant(x, n + 1, 8) - x)
    # nested grids: every 2^-n point is a 2^-(n+1) point, so the fine
    # error can never exceed the coarse error POINTWISE
    assert jnp.all(err_fine <= err_coarse + 1e-7)


@settings(max_examples=50, deadline=None)
@given(shift=st.integers(-6, 10), seed=st.integers(0, 2**16))
def test_shift_requant_sign(shift, seed):
    # |acc| < 2^15 keeps acc * 2^-shift exact in f32 for the reference
    acc = jnp.asarray(np.random.default_rng(seed).integers(
        -(1 << 15), 1 << 15, size=256), jnp.int32)
    got = Q.shift_requant(acc, shift)
    ref = jnp.clip(Q.round_half_away(acc.astype(jnp.float32) * 2.0 ** -shift),
                   -128, 127).astype(jnp.int8)
    assert jnp.array_equal(got, ref), f"shift={shift}"
    if shift < 0:
        # negative shift == exact left shift (the RTL's other direction)
        assert jnp.array_equal(
            got, jnp.clip(acc << -shift, -128, 127).astype(jnp.int8))


@settings(max_examples=6, deadline=None)
@given(n_b=st.integers(0, 12), relu=st.booleans(), seed=st.integers(0, 999))
def test_int8_matmul_bias_shift_sign_property(n_b, relu, seed):
    """Kernel vs jnp reference across the bias_shift sign boundary
    (n_x + n_w = 5, so n_b > 5 exercises the negative left-shift branch
    the PR 1 fix covers).  m, k, n above launch thresholds so the Pallas
    kernel body genuinely executes."""
    spec = LinearQuantSpec(n_x=2, n_w=3, n_b=n_b, n_o=4, bits=8)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-128, 128, size=(16, 128)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, size=(128, 128)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, size=(128,)), jnp.int8)
    got = ops.int8_matmul(x, w, b, spec, relu=relu, force_kernel=True)
    ref = int_linear(x, w, b, spec, apply_relu=relu)
    assert jnp.array_equal(got, ref), f"bias_shift={spec.bias_shift}"


@settings(max_examples=30, deadline=None)
@given(shift=st.integers(-8, -1), seed=st.integers(0, 2**16))
def test_shift_requant_negative_saturates_instead_of_wrapping(shift, seed):
    """ISSUE 5 regression: an accumulator near 2^31 / 2^|shift| must
    SATURATE through the negative-shift (left-shift) path.  The old
    ``acc << -shift`` wrapped int32 BEFORE the clip, so a large positive
    accumulator came out as -128 (sign-flipped codes) instead of 127 —
    both the jnp reference and the Pallas epilogue helper are covered."""
    from repro.kernels.int8_matmul import _shift_requant_i32
    s = -shift
    edge = (2**31 - 1) >> s             # largest magnitude that shifts exact
    rng = np.random.default_rng(seed)
    acc = jnp.asarray(np.concatenate([
        rng.integers(edge - 4, 2**31 - 1, size=64),     # wrap zone
        -rng.integers(edge - 4, 2**31 - 1, size=64),
        rng.integers(-(1 << 12), 1 << 12, size=64),     # exact zone
    ]), jnp.int32)
    ref = jnp.clip(
        jnp.round(acc.astype(jnp.float64) * 2.0 ** s), -128, 127
    ).astype(jnp.int8)
    got = Q.shift_requant(acc, shift)
    assert jnp.array_equal(got, ref), f"shift={shift}"
    got_k = _shift_requant_i32(acc, shift, -128, 127).astype(jnp.int8)
    assert jnp.array_equal(got_k, ref), f"kernel helper, shift={shift}"
    # the old bug, pinned: the largest positive accumulators must map to
    # +127, never to the negative rail
    assert int(got[0]) == 127 and int(got[64]) == -128
