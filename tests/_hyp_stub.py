"""Minimal fallback for ``hypothesis`` when it isn't installed.

The tier-1 container does not ship hypothesis; these shims keep the
property tests runnable as deterministic sampled sweeps (seeded rng, so
failures reproduce).  Interface-compatible with the subset the test
suite uses: ``@settings(max_examples=N, deadline=None)`` stacked on
``@given(name=st.integers(lo, hi) | st.sampled_from(seq))``.
"""
from __future__ import annotations

import functools

import numpy as np

__all__ = ["given", "settings", "st", "strategies"]

_DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    @staticmethod
    def sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: opts[int(rng.integers(len(opts)))])

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))


st = strategies = _Strategies()


def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
            rng = np.random.default_rng(0)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in strats.items()}
                fn(*args, **drawn, **kwargs)

        # pytest resolves fixture needs from inspect.signature, which follows
        # __wrapped__ back to the parametrized original — drop it so the
        # (*args, **kwargs) wrapper signature wins.
        del wrapper.__wrapped__
        return wrapper

    return deco
