"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps + hypothesis.

Kernels run in interpret mode on CPU (the kernel BODY executes, so the
tiling/epilogue logic is what's validated; MXU lowering is the TPU target).
"""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container lacks hypothesis
    from _hyp_stub import given, settings, st

from repro.core.integer_ops import LinearQuantSpec
from repro.kernels import ops, ref


def _codes(shape, seed, lo=-128, hi=128):
    return jnp.asarray(
        np.random.default_rng(seed).integers(lo, hi, size=shape), jnp.int8)


@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (256, 512, 512),
                                   (64, 128, 384), (200, 300, 130)])
@pytest.mark.parametrize("has_bias", [False, True])
def test_int8_matmul_shapes(m, k, n, has_bias):
    x, w = _codes((m, k), 1), _codes((k, n), 2)
    b = _codes((n,), 3) if has_bias else None
    spec = LinearQuantSpec(n_x=4, n_w=8, n_b=7, n_o=4)
    out = ops.int8_matmul(x, w, b, spec, force_kernel=True)
    expect = ref.int8_matmul_ref(x, w, b, shift=spec.requant_shift,
                                 bias_shift=spec.bias_shift)
    assert out.dtype == jnp.int8
    assert np.array_equal(np.asarray(out), np.asarray(expect))


def test_int8_matmul_negative_bias_shift():
    """Regression: bias grid finer than the accumulator grid (n_b > n_x+n_w).

    The epilogue used to pass ``-(-bias_shift)`` (still negative) into the
    shift helper, turning the intended rounding right-shift into a left
    shift — off by up to 2^(2|shift|) per bias element.
    """
    x, w = _codes((128, 256), 21), _codes((256, 128), 22)
    b = _codes((128,), 23)
    spec = LinearQuantSpec(n_x=2, n_w=2, n_b=10, n_o=1)
    assert spec.bias_shift < 0  # the buggy branch
    out = ops.int8_matmul(x, w, b, spec, force_kernel=True)
    expect = ref.int8_matmul_ref(x, w, b, shift=spec.requant_shift,
                                 bias_shift=spec.bias_shift)
    assert np.array_equal(np.asarray(out), np.asarray(expect))


def test_int8_matmul_batch_dims():
    x = _codes((4, 32, 256), 5)
    w = _codes((256, 128), 6)
    spec = LinearQuantSpec(n_x=4, n_w=8, n_b=8, n_o=4)
    out = ops.int8_matmul(x, w, None, spec, force_kernel=True)
    expect = ref.int8_matmul_ref(x.reshape(-1, 256), w, None,
                                 shift=spec.requant_shift).reshape(4, 32, 128)
    assert np.array_equal(np.asarray(out), np.asarray(expect))


def test_int8_matmul_fused_relu():
    x, w = _codes((128, 256), 7), _codes((256, 128), 8)
    spec = LinearQuantSpec(n_x=4, n_w=8, n_b=8, n_o=4, out_unsigned=True)
    out = ops.int8_matmul(x, w, None, spec, relu=True, force_kernel=True)
    expect = ref.int8_matmul_ref(x, w, None, shift=spec.requant_shift,
                                 relu=True, lo=0, hi=255, out_dtype=jnp.uint8)
    assert out.dtype == jnp.uint8
    assert np.array_equal(np.asarray(out), np.asarray(expect))


def test_int8_matmul_padded_tiles_negative_bias_shift():
    """Regression (W8A8 serving): zero-padded tiles cannot leak through
    bias-align under a negative ``bias_shift``.

    With k, n > 512 and non-multiples of the (bm, bk, bn) = (128, 512,
    512) tile quanta, the kernel genuinely zero-pads K and N (smaller
    operands clamp the block to the operand and never pad — see
    ``_pick_blocks``).  A finer-than-accumulator bias grid (n_b > n_x +
    n_w, bias_shift < 0) then routes every padded column's zero bias
    through the rounding right-shift; the contract is that a zero
    contribution stays exactly zero through BOTH shift signs, so the
    valid region must equal the unpadded integer reference bit-for-bit.
    """
    from repro.core.integer_ops import int_linear
    m, k, n = 150, 600, 640                 # pads to (256, 1024, 1024)
    x, w = _codes((m, k), 31), _codes((k, n), 32)
    b = _codes((n,), 33)
    spec = LinearQuantSpec(n_x=2, n_w=3, n_b=9, n_o=4)
    assert spec.bias_shift < 0 and spec.requant_shift > 0
    out = ops.int8_matmul(x, w, b, spec, force_kernel=True)
    assert out.shape == (m, n)              # padding stripped
    expect = int_linear(x, w, b, spec)      # serving's jnp reference path
    assert np.array_equal(np.asarray(out), np.asarray(expect))
    # saturating bias codes + fused relu on the same padded grid
    spec_r = LinearQuantSpec(n_x=2, n_w=3, n_b=9, n_o=4, out_unsigned=True)
    b_sat = jnp.where(jnp.arange(n) % 2 == 0, 127, -128).astype(jnp.int8)
    out_r = ops.int8_matmul(x, w, b_sat, spec_r, relu=True,
                            force_kernel=True)
    assert np.array_equal(np.asarray(out_r),
                          np.asarray(int_linear(x, w, b_sat, spec_r,
                                                apply_relu=True)))


@pytest.mark.parametrize("rows,cols", [(8, 128), (256, 512), (100, 640),
                                       (1024, 2048)])
@pytest.mark.parametrize("unsigned", [False, True])
def test_quantize_kernel(rows, cols, unsigned):
    x = jnp.asarray(np.random.default_rng(9).normal(size=(rows, cols)) * 4,
                    jnp.float32)
    out = ops.quantize_act(x, 4, unsigned=unsigned)
    expect = ref.quantize_ref(x, n=4, unsigned=unsigned)
    assert np.array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("rows,cols", [(16, 128), (256, 384)])
@pytest.mark.parametrize("relu", [False, True])
def test_residual_requant_kernel(rows, cols, relu):
    a, b = _codes((rows, cols), 10), _codes((rows, cols), 11)
    out = ops.residual_requant(a, b, n_a=5, n_b=3, n_o=4, relu=relu)
    expect = ref.residual_requant_ref(a, b, n_a=5, n_b=3, n_o=4, relu=relu)
    assert np.array_equal(np.asarray(out), np.asarray(expect))


@settings(max_examples=15, deadline=None)
@given(m=st.integers(16, 80), k=st.integers(128, 300), n=st.integers(128, 300),
       shift_in=st.integers(2, 12), seed=st.integers(0, 2**31 - 1))
def test_property_int8_matmul_any_shape(m, k, n, shift_in, seed):
    x = _codes((m, k), seed)
    w = _codes((k, n), seed + 1)
    spec = LinearQuantSpec(n_x=shift_in // 2, n_w=shift_in - shift_in // 2,
                           n_b=4, n_o=2)
    out = ops.int8_matmul(x, w, None, spec, force_kernel=True)
    expect = ref.int8_matmul_ref(x, w, None, shift=spec.requant_shift)
    assert np.array_equal(np.asarray(out), np.asarray(expect))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(-2, 9), rows=st.integers(4, 40),
       cols=st.integers(100, 600), seed=st.integers(0, 2**31 - 1))
def test_property_quantize_matches_core(n, rows, cols, seed):
    from repro.core.qscheme import quant
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(rows, cols)),
                    jnp.float32)
    assert np.array_equal(np.asarray(ops.quantize_act(x, n)),
                          np.asarray(quant(x, n, 8)))
