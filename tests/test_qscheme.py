"""Unit + property tests for the Eq. 1 quantization scheme."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container lacks hypothesis
    from _hyp_stub import given, settings, st

from repro.core import qscheme as Q


def test_quant_dequant_roundtrip_exact_on_grid():
    # values already on the 2^-n grid must be exact (equal conversion
    # between integer and float representation — paper §1.1)
    n = 4
    vals = jnp.arange(-128, 128, dtype=jnp.float32) * 2.0 ** -n
    q = Q.quant(vals, n, 8)
    assert jnp.all(Q.dequant(q, n) == vals)


def test_fake_quant_equals_dequant_quant():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(128, 64)),
                    jnp.float32)
    for n in (-2, 0, 3, 7):
        fq = Q.fake_quant(x, n, 8)
        assert np.allclose(fq, Q.dequant(Q.quant(x, n, 8), n))


def test_negative_fractional_bits_select_high_digits():
    # N_r = -3 with 8-bit width keeps digits 3..10 before the binary point
    x = jnp.asarray([1024.0, 8.0, 1000.0])
    fq = Q.fake_quant(x, -3, 8)
    assert float(fq[0]) == 1016.0  # clipped at 127 * 8
    assert float(fq[1]) == 8.0
    assert float(fq[2]) == 1000.0


def test_unsigned_range_post_relu():
    x = jnp.linspace(0, 3, 100)
    fq = Q.fake_quant(x, 6, 8, unsigned=True)
    q = Q.quant(x, 6, 8, unsigned=True)
    assert q.dtype == jnp.uint8
    assert int(q.max()) <= 255 and int(q.min()) >= 0
    assert float(jnp.max(jnp.abs(fq - jnp.clip(x, 0, 255 / 64)))) <= 2.0 ** -7


def test_round_half_away():
    x = jnp.asarray([0.5, 1.5, -0.5, -1.5, 2.5])
    r = Q.round_half_away(x)
    assert list(np.asarray(r)) == [1.0, 2.0, -1.0, -2.0, 3.0]


def test_ste_gradient_passes_inside_clips_outside():
    n, bits = 3, 8
    g = jax.grad(lambda x: jnp.sum(Q.fake_quant_ste(x, jnp.asarray(n), bits)))
    x = jnp.asarray([0.1, 100.0, -100.0, 1.0])  # 100*8 >> 127 -> clipped
    gx = g(x)
    assert list(np.asarray(gx)) == [1.0, 0.0, 0.0, 1.0]


def test_shift_requant_matches_float_path():
    """Integer shift requant == fake-quant arithmetic (paper Eq. 3/4)."""
    rng = np.random.default_rng(1)
    acc = jnp.asarray(rng.integers(-2**20, 2**20, size=(256,)), jnp.int32)
    n_in, n_out = 12, 5          # shift = 7
    out_int = Q.shift_requant(acc, n_in - n_out, bits=8)
    float_path = Q.quant(Q.dequant(acc, n_in), n_out, 8)
    assert np.array_equal(np.asarray(out_int), np.asarray(float_path))


def test_shift_requant_negative_shift_left_shifts():
    acc = jnp.asarray([3, -3], jnp.int32)
    out = Q.shift_requant(acc, -2, bits=8)
    assert list(np.asarray(out)) == [12, -12]


@settings(max_examples=50, deadline=None)
@given(n=st.integers(-4, 10), bits=st.sampled_from([4, 6, 8]),
       seed=st.integers(0, 2**31 - 1))
def test_property_quantization_error_bound(n, bits, seed):
    """|Q(r) - r| <= 2^{-n-1} for r inside the representable range."""
    rng = np.random.default_rng(seed)
    lo, hi = Q.int_bounds(bits)
    span = (hi - 1) * 2.0 ** -n
    x = jnp.asarray(rng.uniform(-span, span, size=64), jnp.float32)
    err = jnp.abs(Q.fake_quant(x, n, bits) - x)
    assert float(jnp.max(err)) <= 2.0 ** (-n - 1) + 1e-6 * 2.0 ** -n


@settings(max_examples=30, deadline=None)
@given(n=st.integers(0, 8), seed=st.integers(0, 2**31 - 1))
def test_property_idempotent(n, seed):
    """Quantization is a projection: Q(Q(x)) == Q(x)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=128), jnp.float32)
    fq = Q.fake_quant(x, n, 8)
    assert np.array_equal(np.asarray(Q.fake_quant(fq, n, 8)), np.asarray(fq))


@settings(max_examples=30, deadline=None)
@given(shift=st.integers(0, 20), seed=st.integers(0, 2**31 - 1))
def test_property_shift_requant_monotone(shift, seed):
    """Requantization preserves order (a shifter cannot swap magnitudes)."""
    rng = np.random.default_rng(seed)
    acc = np.sort(rng.integers(-2**24, 2**24, size=64)).astype(np.int32)
    out = np.asarray(Q.shift_requant(jnp.asarray(acc), shift, bits=8))
    assert np.all(np.diff(out.astype(np.int32)) >= 0)
