"""Integer-only ops (Eq. 2-4): bit-exactness vs the float emulation."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import integer_ops as IO
from repro.core import qscheme as Q


def _rand(shape, scale=1.0, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape) * scale,
                       jnp.float32)


def test_int_linear_bit_exact_vs_fake_quant():
    x, w, b = _rand((32, 64), 1.0, 0), _rand((64, 48), 0.05, 1), \
        _rand((48,), 0.1, 2)
    spec = IO.LinearQuantSpec(n_x=4, n_w=8, n_b=7, n_o=3)
    xi, wi, bi = Q.quant(x, 4), Q.quant(w, 8), Q.quant(b, 7)
    out_int = IO.int_linear(xi, wi, bi, spec)
    float_path = Q.quant(
        Q.dequant(xi, 4) @ Q.dequant(wi, 8) + Q.dequant(bi, 7), 3, 8)
    assert np.array_equal(np.asarray(out_int), np.asarray(float_path))


def test_int_linear_fused_relu_unsigned():
    x, w = _rand((16, 32), 1.0, 3), _rand((32, 16), 0.1, 4)
    spec = IO.LinearQuantSpec(n_x=4, n_w=7, n_b=7, n_o=4, out_unsigned=True)
    xi, wi = Q.quant(x, 4), Q.quant(w, 7)
    out = IO.int_linear(xi, wi, None, spec, apply_relu=True)
    assert out.dtype == jnp.uint8
    ref = Q.quant(jnp.maximum(Q.dequant(xi, 4) @ Q.dequant(wi, 7), 0), 4, 8,
                  unsigned=True)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_int_conv2d_matches_float_emulation():
    x = _rand((2, 8, 8, 3), 1.0, 5)
    w = _rand((3, 3, 3, 4), 0.2, 6)
    b = _rand((4,), 0.1, 7)
    spec = IO.LinearQuantSpec(n_x=5, n_w=6, n_b=6, n_o=3)
    xi, wi, bi = Q.quant(x, 5), Q.quant(w, 6), Q.quant(b, 6)
    out = IO.int_conv2d(xi, wi, bi, spec)
    import jax
    acc = jax.lax.conv_general_dilated(
        Q.dequant(xi, 5), Q.dequant(wi, 6), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + Q.dequant(bi, 6)
    ref = Q.quant(acc, 3, 8)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_residual_add_alignment_is_exact():
    """Fig. 1(c): shifting both operands to the finer grid loses nothing."""
    a = _rand((64,), 1.0, 8)
    b = _rand((64,), 0.3, 9)
    n_a, n_b, n_o = 5, 3, 4
    ai, bi = Q.quant(a, n_a), Q.quant(b, n_b)
    out = IO.int_residual_add(ai, n_a, bi, n_b, n_o)
    ref = Q.quant(Q.dequant(ai, n_a) + Q.dequant(bi, n_b), n_o, 8)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_residual_add_relu_case_c():
    a = _rand((64,), 1.0, 10)
    b = _rand((64,), 1.0, 11)
    ai, bi = Q.quant(a, 4), Q.quant(b, 4)
    out = IO.int_residual_add(ai, 4, bi, 4, 4, apply_relu=True)
    assert out.dtype == jnp.uint8
    ref = Q.quant(jnp.maximum(Q.dequant(ai, 4) + Q.dequant(bi, 4), 0), 4, 8,
                  unsigned=True)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_bias_align_left_shift():
    b = jnp.asarray([1, -2, 127], jnp.int8)
    out = IO.bias_align(b, 4)
    assert list(np.asarray(out)) == [16, -32, 2032]
