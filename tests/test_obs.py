"""Observability layer (DESIGN §14): metrics registry, trace ring,
energy accounting, and the golden report schema.

The engine-integration half runs ONE small mixed workload (speculation +
prefix cache + tracing all on) through a module-scoped engine and then
asserts every §14 contract against that single run: the report is a
nested view of the registry and matches the committed GOLDEN_SCHEMA;
trace-derived TTFT/TPOT/e2e percentiles equal the legacy
request-timestamp percentiles EXACTLY (the marks reuse the same clock
reads); the phase-split energy proxy reconciles exactly with the
Table-5 requant counters; the exported trace validates against the
Chrome trace-event schema; and the duplicated ``retracts`` fields are
declared aliases that cannot diverge.
"""
import dataclasses
import json
import math

import numpy as np
import pytest

from repro.core import hwcost
from repro.obs.metrics import (Counter, FuncMetric, Gauge, Histogram,
                               MetricsRegistry, prom_name)
from repro.obs.profile import ENERGY_PHASES, EnergyAccount, Profiler
from repro.obs.schema import GOLDEN_SCHEMA, diff_schema, schema_of
from repro.obs.trace import Tracer, validate_chrome_trace
from repro.serving.engine import _pct, summarize_step_times


# ---------------------------------------------------------------------------
# metrics registry (pure python)
# ---------------------------------------------------------------------------

def test_counter_unlabeled_and_labeled():
    c = Counter("x.ops", "ops", label_names=("phase",))
    c.inc(3, phase="prefill")
    c.inc(2, phase="decode")
    c.inc(1, phase="prefill")
    assert c.get() == 6
    assert c.get(phase="prefill") == 4
    assert c.value() == {"phase=decode": 2, "phase=prefill": 4}
    c.reset()
    assert c.get() == 0 and c.value() == {}
    u = Counter("y", "plain")
    u.inc()
    u.inc(4)
    assert u.value() == 5


def test_gauge_and_func_metric():
    g = Gauge("g", "a gauge")
    g.set(2.5)
    g.add(0.5)
    assert g.value() == 3.0
    src = {"v": 7}
    f = FuncMetric("f", "bound", lambda: src["v"], kind="counter")
    assert f.value() == 7
    src["v"] = 9
    assert f.value() == 9          # read at snapshot time, not bind time
    f.reset()                      # bound metrics follow their source
    assert f.value() == 9
    with pytest.raises(ValueError):
        FuncMetric("f", "bad kind", lambda: 0, kind="summary")


def test_histogram_percentile_upper_bound_never_interpolates():
    h = Histogram("h", "lat", buckets=[0.001, 0.01, 0.1])
    assert h.percentile(50) is None
    for v in (0.0005, 0.002, 0.003, 0.05):
        h.observe(v)
    assert h.n == 4
    # p50 sample is 0.002/0.003 -> bucket upper bound 0.01, not a blend
    assert h.percentile(50) == 0.01
    assert h.percentile(99) == 0.1
    h.observe(5.0)                 # lands in +Inf
    assert h.percentile(99) == math.inf
    val = h.value()
    assert val["count"] == 5 and val["buckets"]["+Inf"] == 1


def test_registry_rejects_duplicates_and_undocumented():
    m = MetricsRegistry()
    m.counter("a", "doc")
    with pytest.raises(ValueError):
        m.counter("a", "again")
    with pytest.raises(ValueError):
        m.counter("b", "")
    assert "a" in m and len(m) == 1


def test_registry_alias_check_is_deferred():
    m = MetricsRegistry()
    # alias registered BEFORE its canonical target (report order allows
    # speculative.* to precede pool.*) — only check_aliases enforces it
    m.func("view.n", "view", lambda: 0, alias_of="canon.n")
    with pytest.raises(ValueError):
        m.check_aliases()
    m.func("canon.n", "canonical", lambda: 0)
    m.check_aliases()


def test_registry_nested_and_reset_owned_only():
    m = MetricsRegistry()
    m.counter("top", "t")
    m.counter("sec.a", "a")
    m.gauge("sec.deep.b", "b")
    src = {"v": 3}
    m.func("sec.bound", "bound", lambda: src["v"])
    m.get("top").inc(2)
    m.get("sec.a").inc(1)
    m.get("sec.deep.b").set(1.5)
    assert m.nested() == {"top": 2,
                          "sec": {"a": 1, "deep": {"b": 1.5}, "bound": 3}}
    assert list(m.snapshot()) == ["top", "sec.a", "sec.deep.b",
                                  "sec.bound"]
    m.reset()
    assert m.get("top").value() == 0
    assert m.get("sec.bound").value() == 3      # bound follows its source


def test_prometheus_exposition():
    m = MetricsRegistry()
    m.counter("pool.allocs", "blocks allocated").inc(4)
    m.gauge("engine.util", "utilization").set(0.5)
    m.func("engine.mode", "serving mode", lambda: "ragged")
    m.func("engine.maybe", "optional value", lambda: None, optional=True)
    h = m.histogram("step.time", "step seconds", buckets=[0.01, 0.1])
    h.observe(0.005)
    h.observe(0.05)
    text = m.to_prometheus()
    assert "# TYPE pool_allocs counter\npool_allocs 4" in text
    assert "engine_util 0.5" in text
    assert 'engine_mode_info{value="ragged"} 1' in text
    assert 'engine_maybe_info{value="none"} 1' in text
    assert 'step_time_bucket{le="0.01"} 1' in text
    assert 'step_time_bucket{le="+Inf"} 2' in text
    assert "step_time_count 2" in text
    assert prom_name("a.b-c d") == "a_b_c_d"


# ---------------------------------------------------------------------------
# tracer (pure python)
# ---------------------------------------------------------------------------

def test_ring_is_bounded_and_counts_drops():
    tr = Tracer(capacity=8, clock=lambda: 0.0, enabled=True)
    for i in range(30):
        tr.event(f"e{i}", "pool")
    assert len(tr.events) == 8
    assert tr.n_emitted == 30
    assert tr.dropped == 22
    # oldest dropped first: the ring holds the most recent 8
    assert [e[1] for e in tr.events] == [f"e{i}" for i in range(22, 30)]
    tr.reset()
    assert len(tr.events) == 0 and tr.dropped == 0


def test_disabled_tracer_records_nothing_but_timelines_stay_on():
    tr = Tracer(capacity=8, clock=lambda: 0.0, enabled=False)
    tr.event("e", "pool")
    tr.span("s", "dispatch", 0.0, 1.0)
    assert tr.n_emitted == 0 and not tr.events
    tr.req_submit(1, arrival=0.5)
    tr.req_mark(1, "first_token", 1.5)
    tr.req_token(1, 1.5)                       # ring-gated: dropped
    tr.req_done(1, 2.5, n_generated=3)
    tl = tr.timelines[1]
    assert tl.ttft == 1.0 and tl.e2e == 2.0
    assert tl.tpot == pytest.approx(0.5)
    assert tl.tokens == []


def test_timeline_marks_are_first_occurrence_wins():
    tr = Tracer(capacity=8, enabled=False)
    tr.req_submit(7, arrival=1.0)
    tr.req_submit(7, arrival=99.0)             # re-queue keeps original
    tr.req_mark(7, "admit", 2.0)
    tr.req_mark(7, "admit", 50.0)              # resume is not admission
    tr.req_preempt(7)
    tr.req_done(7, 5.0, n_generated=1)
    tr.req_done(7, 90.0, n_generated=9)
    tl = tr.timelines[7]
    assert (tl.arrival, tl.admit, tl.done) == (1.0, 2.0, 5.0)
    assert tl.n_generated == 1 and tl.preemptions == 1
    assert tl.tpot is None                     # needs n_generated >= 2


def test_derive_latencies_skips_unfinished():
    tr = Tracer(capacity=8, enabled=False)
    tr.req_submit(0, 0.0)
    tr.req_mark(0, "first_token", 1.0)
    tr.req_done(0, 3.0, n_generated=5)
    tr.req_submit(1, 0.0)                      # never finished
    lat = tr.derive_latencies()
    assert lat["ttft"] == [1.0] and lat["e2e"] == [3.0]
    assert lat["tpot"] == [pytest.approx(0.5)]


def test_chrome_export_schema():
    tr = Tracer(capacity=16, clock=lambda: 0.0, enabled=True)
    tr.event("pool.alloc", "pool", ts=0.001, args={"seq": 1})
    tr.span("ragged_step", "dispatch", 0.002, 0.003,
            {"shape": "T8xS2", "compile": True})
    tr.req_submit(0, 0.0)
    tr.req_mark(0, "admit", 0.001)
    tr.req_mark(0, "first_token", 0.004)
    tr.req_done(0, 0.01, n_generated=4)
    obj = tr.to_chrome()
    assert validate_chrome_trace(obj) == []
    ev = obj["traceEvents"]
    spans = [e for e in ev if e["ph"] == "X"]
    names = {e["name"] for e in ev}
    assert "ragged_step" in names and "req 0" in names
    assert "first_token rid=0" in names
    step = next(e for e in spans if e["name"] == "ragged_step")
    assert step["ts"] == 2000.0 and step["dur"] == 3000.0   # seconds->us
    req = next(e for e in spans if e["name"] == "req 0")
    assert req["args"]["ttft_s"] == pytest.approx(0.004)
    assert obj["otherData"]["dropped_events"] == 0
    json.dumps(obj)                            # file-writable


def test_validate_chrome_trace_rejects_malformed():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"foo": 1}) != []
    bad_phase = {"traceEvents": [
        {"name": "e", "ph": "Z", "ts": 0, "pid": 0, "tid": 0}]}
    assert any("phase" in p for p in validate_chrome_trace(bad_phase))
    no_dur = {"traceEvents": [
        {"name": "e", "ph": "X", "ts": 0, "pid": 0, "tid": 0}]}
    assert any("dur" in p for p in validate_chrome_trace(no_dur))
    missing = {"traceEvents": [{"ph": "i", "ts": 0, "pid": 0}]}
    probs = validate_chrome_trace(missing)
    assert any("name" in p for p in probs) and any("tid" in p
                                                   for p in probs)


# ---------------------------------------------------------------------------
# energy account (pure python)
# ---------------------------------------------------------------------------

def test_energy_account_phases_and_per_token_semantics():
    en = EnergyAccount("bit_shifting")
    with pytest.raises(ValueError):
        EnergyAccount("free_lunch")
    assert en.uj_per_token("prefill") is None          # 0 ops / 0 toks
    en.charge("prefill", 1000, 10)
    en.charge("decode", 500, 5)
    en.charge("spec_wasted", 200, 2)
    assert en.total_quant_ops == 1700
    pj = hwcost.energy_per_op_pj("bit_shifting")
    assert en.energy_uj("prefill") == pytest.approx(1000 * pj * 1e-6)
    assert en.uj_per_token("prefill") == pytest.approx(
        en.energy_uj("prefill") / 10)
    # spec_wasted amortizes over EMITTED decode tokens, not wasted rows
    assert en.uj_per_token("spec_wasted") == pytest.approx(
        en.energy_uj("spec_wasted") / 5)
    assert en.proxy_uj_per_token() == pytest.approx(
        hwcost.estimate("bit_shifting", 1700).energy_uj / 15)
    rep = en.report()
    assert rep["unit"] == "bit_shifting"
    assert set(ENERGY_PHASES) <= set(rep)
    assert rep["total_quant_ops"] == 1700
    en.reset()
    assert en.total_quant_ops == 0 and en.proxy_uj_per_token() is None


def test_energy_ops_without_tokens_is_inf_not_crash():
    en = EnergyAccount()
    en.charge("decode", 100, 0)
    assert en.uj_per_token("decode") == float("inf")


def test_profiler_disabled_is_inert():
    p = Profiler()
    assert not p.enabled and p.report() is None
    with p.capture():
        pass
    with p.step_annotation("step", 0):
        pass
    assert p.cost_for(("ragged", 8, 2), None) is None


# ---------------------------------------------------------------------------
# summarize_step_times edge cases (obs satellite)
# ---------------------------------------------------------------------------

def test_step_times_empty_and_tiny_sample_lists():
    assert summarize_step_times({}) == {}
    out = summarize_step_times({("ragged", 8, 2): []})
    assert out["ragged_8xS2"] == {"calls": 0, "first_s": None,
                                  "steady_s": None, "p99_s": None}
    out = summarize_step_times({("ragged", 8, 2): [0.5]})
    assert out["ragged_8xS2"] == {"calls": 1, "first_s": 0.5,
                                  "steady_s": None, "p99_s": None}
    # one steady sample: a median exists, a p99 tail bound does not
    out = summarize_step_times({("ragged", 8, 2): [0.5, 0.1]})
    assert out["ragged_8xS2"] == {"calls": 2, "first_s": 0.5,
                                  "steady_s": 0.1, "p99_s": None}
    out = summarize_step_times({("ragged", 8, 2): [0.5, 0.1, 0.3]})
    e = out["ragged_8xS2"]
    assert e["calls"] == 3 and e["steady_s"] == 0.2
    assert e["p99_s"] == round(_pct([0.1, 0.3], 99), 4)


def test_step_times_never_index_errors_across_key_kinds():
    out = summarize_step_times({
        ("ragged", 8, 2): [],
        ("decode", 4): [0.2],
        "prefill_1x32": [],
    })
    assert out["legacy_shapes"]["decodex4"]["calls"] == 1
    assert out["prefill_1x32"]["calls"] == 0


# ---------------------------------------------------------------------------
# engine integration: one small traced run, every §14 contract
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_run():
    import jax
    from repro.configs import get_smoke_config
    from repro.core.qmodel import QuantContext, QuantMode
    from repro.models import model as M
    from repro.serving import Request, ServingEngine

    cfg = dataclasses.replace(
        get_smoke_config("qwen3_1_7b").scaled(dtype="float32"),
        kv_cache_bits=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, QuantContext(mode=QuantMode.FP),
                        n_slots=2, block_size=8, max_model_len=64,
                        spec_k=3, prefix_cache=True, trace=True)
    rng = np.random.default_rng(0)
    reqs, t = [], 0.0
    for i in range(4):
        t += float(rng.exponential(0.02))
        # one repetitive prompt so the ngram drafter proposes something
        prompt = (np.tile(rng.integers(0, cfg.vocab_size, size=3), 5)
                  if i == 1 else
                  rng.integers(0, cfg.vocab_size,
                               size=int(rng.integers(5, 20))))
        reqs.append(Request(rid=i, prompt=prompt.astype(np.int32),
                            max_new_tokens=int(rng.integers(3, 9)),
                            arrival=t))
    rep = eng.run(reqs)
    return eng, rep


def test_golden_schema_matches_registry(traced_run):
    eng, _ = traced_run
    errs = diff_schema(schema_of(eng.metrics), spec=True, cache=True)
    assert errs == [], "\n".join(errs)
    eng.metrics.check_aliases()
    for name, m in ((n, eng.metrics.get(n)) for n in eng.metrics.names()):
        assert m.help.strip(), f"{name} has no help text"


def test_report_is_nested_registry_view(traced_run):
    eng, rep = traced_run
    nested = eng.metrics.nested()
    # all attention-substrate sections enabled; the slab section is
    # substrate-exclusive and surfaces as an explicit None (§16)
    nested.setdefault("state_pool", None)
    assert rep == nested
    # every snapshot value is JSON-serializable with documented type
    snap = eng.metrics.snapshot()
    json.dumps(snap)
    for name, val in snap.items():
        d = eng.metrics.get(name)
        if val is None:
            assert d.optional, f"{name} is None but not declared optional"
        else:
            assert isinstance(val, d.typ) or (
                d.typ is float and isinstance(val, int)), \
                f"{name}: {type(val).__name__} is not declared {d.typ}"


def test_timeline_percentiles_match_legacy_exactly(traced_run):
    _, rep = traced_run
    for sec in ("ttft_s", "tpot_s", "e2e_s"):
        assert rep["timeline"][sec] == rep[sec], sec
    assert rep["timeline"]["completed"] == rep["completed"]


def test_energy_reconciles_exactly_with_hwcost(traced_run):
    _, rep = traced_run
    en, hw = rep["energy"], rep["hwcost"]
    assert en["total_quant_ops"] == (hw["requant_ops_performed"]
                                     + hw["requant_ops_forward"])
    assert en["total_quant_ops"] == sum(
        en[p]["quant_ops"] for p in ENERGY_PHASES)
    assert en["spec_wasted"]["quant_ops"] == \
        hw["requant_ops_wasted_speculation"]
    # useful-token accounting: prefill fed every prompt token, decode
    # emitted everything past each request's first token
    assert en["prefill"]["tokens"] == rep["prompt_tokens"]
    assert en["decode"]["tokens"] == rep["gen_tokens"] - rep["completed"]
    assert en["total_energy_uj"] == pytest.approx(
        hw["energy_uj_bit_shift"], abs=1e-6)


def test_retract_fields_are_aliases_and_never_diverge(traced_run):
    eng, rep = traced_run
    assert rep["speculative"]["retracts"] == rep["pool"]["retracts"]
    assert rep["speculative"]["retracted_blocks"] == \
        rep["pool"]["retracted_blocks"]
    assert eng.metrics.get("speculative.retracts").alias_of == \
        "pool.retracts"
    # same source by construction: bump the canonical counter and both
    # views move together
    eng.pool.stats.retracts += 1
    try:
        assert eng.metrics.get("speculative.retracts").value() == \
            eng.metrics.get("pool.retracts").value()
    finally:
        eng.pool.stats.retracts -= 1


def test_trace_exports_valid_chrome_json(tmp_path, traced_run):
    eng, rep = traced_run
    path = tmp_path / "trace.json"
    obj = eng.tracer.export(str(path))
    assert validate_chrome_trace(obj) == []
    assert validate_chrome_trace(json.loads(path.read_text())) == []
    names = {e["name"] for e in obj["traceEvents"]}
    # span taxonomy: dispatches, scheduler, pool and cache all present
    assert "ragged_step" in names
    assert "sched.admit" in names and "sched.finish" in names
    assert "pool.alloc" in names and "pool.free" in names
    assert "cache.lookup" in names
    assert {f"req {i}" for i in range(4)} <= names
    steps = [e for e in obj["traceEvents"] if e["name"] == "ragged_step"]
    assert len(steps) == rep["ragged_steps"]
    assert sum(e["args"]["compile"] for e in steps) == \
        len([k for k in rep["step_shapes"] if k.startswith("ragged_")])
    for e in steps:
        assert e["args"]["real_tokens"] + e["args"]["padded_tokens"] > 0
    assert sum(e["args"]["real_tokens"] for e in steps) == \
        rep["dispatched_tokens"] - rep["padded_tokens"]


def test_drafter_stats_surface_in_report(traced_run):
    eng, rep = traced_run
    sp = rep["speculative"]
    assert sp["drafter_calls"] == eng.drafter.stats.calls > 0
    assert sp["drafter_proposed"] == eng.drafter.stats.proposed
    assert sp["drafter_empty"] == eng.drafter.stats.empty
    assert sp["drafter_calls"] >= sp["drafter_empty"]
    # every proposed token was either truncated by the engine's budget
    # or drafted into a verify step
    assert sp["drafted_tokens"] <= sp["drafter_proposed"]


def test_prometheus_exposition_from_engine(traced_run):
    eng, rep = traced_run
    text = eng.metrics.to_prometheus()
    assert f"\ngen_tokens {rep['gen_tokens']}\n" in text
    assert "# TYPE pool_allocs counter" in text
    assert "energy_total_quant_ops" in text
    assert 'energy_unit_info{value="bit_shifting"} 1' in text


def test_reset_metrics_clears_obs_state(traced_run):
    eng, _ = traced_run
    assert eng.tracer.n_emitted > 0
    assert eng.energy.total_quant_ops > 0
    eng.reset_metrics()
    assert eng.tracer.n_emitted == 0 and not eng.tracer.timelines
    assert eng.energy.total_quant_ops == 0
    assert eng.drafter.stats.calls == 0
    rep = eng.report()                 # fresh report stays well-defined
    assert rep["completed"] == 0
    assert rep["ttft_s"]["p50"] is None
    assert rep["energy"]["proxy_uj_per_token"] is None
    assert rep["timeline"]["requests"] == 0


def test_disabled_sections_surface_as_none():
    # engine construction only (no dispatch): report must still be
    # complete, with the off sections explicit None per the legacy shape
    import jax
    from repro.configs import get_smoke_config
    from repro.core.qmodel import QuantContext, QuantMode
    from repro.models import model as M
    from repro.serving import ServingEngine

    cfg = dataclasses.replace(
        get_smoke_config("qwen3_1_7b").scaled(dtype="float32"),
        kv_cache_bits=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, QuantContext(mode=QuantMode.FP),
                        n_slots=2, block_size=8, max_model_len=32,
                        spec_k=0, prefix_cache=False)
    rep = eng.report()
    assert rep["speculative"] is None
    assert rep["prefix_cache"] is None
    assert "speculative.spec_k" not in eng.metrics.names()
    errs = diff_schema(schema_of(eng.metrics), spec=False, cache=False)
    assert errs == [], "\n".join(errs)
    assert rep["obs"]["trace_enabled"] is False
    assert rep["energy"]["total_quant_ops"] == 0


# ---------------------------------------------------------------------------
# satellite coverage (ISSUE 9): ring metrics, escaping, percentile
# contract, chrome-validator edge cases
# ---------------------------------------------------------------------------

def test_ring_overflow_moves_dropped_counter_metrics():
    # a DELIBERATELY tiny ring: the registry-facing counter and the
    # occupancy gauge must track the overflow, not just tracer attrs
    import jax
    from repro.configs import get_smoke_config
    from repro.core.qmodel import QuantContext, QuantMode
    from repro.models import model as M
    from repro.serving import Request, ServingEngine

    cfg = dataclasses.replace(
        get_smoke_config("qwen3_1_7b").scaled(dtype="float32"),
        kv_cache_bits=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, QuantContext(mode=QuantMode.FP),
                        n_slots=2, block_size=8, max_model_len=32,
                        trace=True, trace_capacity=8)
    assert eng.metrics.get_value("obs.trace_dropped_total") == 0
    assert eng.metrics.get_value("obs.trace_ring_used") == 0.0
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=6).astype(np.int32),
                    max_new_tokens=4, arrival=0.0) for i in range(3)]
    eng.run(reqs)
    dropped = eng.metrics.get_value("obs.trace_dropped_total")
    assert dropped == eng.tracer.dropped > 0
    assert eng.metrics.get_value("obs.trace_ring_used") == 1.0
    rep = eng.report()
    assert rep["obs"]["trace_dropped_total"] == dropped
    assert rep["obs"]["trace_ring_used"] == 1.0
    eng.reset_metrics()
    assert eng.metrics.get_value("obs.trace_dropped_total") == 0
    assert eng.metrics.get_value("obs.trace_ring_used") == 0.0


def test_prometheus_escaping_round_trips_pathological_strings():
    # prometheus 0.0.4 text format: HELP escapes backslash + newline,
    # label values escape backslash + double-quote + newline.  A parser
    # applying the spec's unescaping must recover the originals.
    nasty_help = 'multi\nline "quoted" back\\slash help'
    nasty_value = 'path\\to\n"thing"'
    m = MetricsRegistry()
    m.counter("nasty.ops", nasty_help, label_names=("k",)).inc(2, k="a\nb")
    m.func("nasty.mode", "mode str", lambda: nasty_value)
    text = m.to_prometheus()
    for line in text.splitlines():
        assert "\r" not in line
    help_line = next(l for l in text.splitlines()
                     if l.startswith("# HELP nasty_ops "))
    escaped = help_line[len("# HELP nasty_ops "):]
    assert "\n" not in escaped
    # spec unescape for HELP: \\ -> \, \n -> newline
    out, i = [], 0
    while i < len(escaped):
        if escaped[i] == "\\" and i + 1 < len(escaped):
            out.append({"n": "\n", "\\": "\\"}[escaped[i + 1]])
            i += 2
        else:
            out.append(escaped[i])
            i += 1
    assert "".join(out) == nasty_help
    series = next(l for l in text.splitlines()
                  if l.startswith("nasty_ops{"))
    assert 'k="a\\nb"' in series and series.endswith(" 2")
    info = next(l for l in text.splitlines()
                if l.startswith("nasty_mode_info{"))
    val = info[info.index('value="') + len('value="'):info.rindex('"')]
    assert (val.replace("\\\\", "\x00").replace('\\"', '"')
            .replace("\\n", "\n").replace("\x00", "\\") == nasty_value)


def test_histogram_percentile_vs_exact_error_bound():
    # the documented contract (Histogram.percentile docstring and
    # Tracer.derive_latencies docstring both cite this test): the
    # bucket-bound percentile is >= the exact rank statistic and
    # overshoots by AT MOST one bucket width; derive_latencies keeps
    # the exact raw samples.
    rng = np.random.default_rng(3)
    samples = rng.uniform(0.0, 0.1, size=97)
    width = 0.01
    edges = [width * k for k in range(1, 11)]      # covers [0, 0.1]
    h = Histogram("h", "lat", buckets=edges)
    for v in samples:
        h.observe(float(v))
    srt = np.sort(samples)
    for q in (1, 10, 25, 50, 75, 90, 99):
        rank = max(1, math.ceil(q / 100.0 * len(srt)))
        exact = float(srt[rank - 1])
        bb = h.percentile(q)
        assert bb >= exact, f"p{q} under-reported: {bb} < {exact}"
        assert bb - exact <= width + 1e-12, \
            f"p{q} error {bb - exact} exceeds one bucket width"
    # exact side of the contract: timelines hand back raw samples
    tr = Tracer(capacity=4, enabled=False)
    tr.req_submit(0, arrival=0.0)
    tr.req_mark(0, "first_token", 0.012)
    tr.req_done(0, 0.05, n_generated=3)
    lat = tr.derive_latencies()
    assert lat["ttft"] == [0.012] and lat["e2e"] == [0.05]


def test_validate_chrome_trace_accepts_edge_cases():
    # empty trace: a capture with zero events is still a valid trace
    assert validate_chrome_trace({"traceEvents": []}) == []
    # events-only object: otherData/displayTimeUnit are optional
    events_only = {"traceEvents": [
        {"name": "e", "ph": "i", "ts": 5.0, "pid": 0, "tid": 1, "s": "t"}]}
    assert validate_chrome_trace(events_only) == []
    # out-of-order timestamps are legal — the chrome loader sorts; the
    # validator must be order-agnostic
    shuffled = {"traceEvents": [
        {"name": "b", "ph": "X", "ts": 900.0, "dur": 1.0,
         "pid": 0, "tid": 0},
        {"name": "a", "ph": "i", "ts": 1.0, "pid": 0, "tid": 0, "s": "t"},
        {"name": "M", "ph": "M", "ts": 0, "pid": 0, "tid": 0,
         "args": {"name": "proc"}}]}
    assert validate_chrome_trace(shuffled) == []
