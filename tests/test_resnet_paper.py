"""The paper-faithful ResNet path: BN folding, Fig. 1 plan, Algorithm 1
calibration, and the integer-only serve path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.resnet_paper import SMOKE_CONFIG
from repro.core.dataflow import count_quant_ops
from repro.models import resnet as R


@pytest.fixture(scope="module")
def setup():
    cfg = SMOKE_CONFIG
    params = R.init_resnet(cfg, jax.random.PRNGKey(0))
    # give BN stats some structure so folding is non-trivial
    for blk in params["blocks"]:
        for c in blk.values():
            c["bn_var"] = c["bn_var"] * 2.0 + 0.5
            c["bn_mean"] = c["bn_mean"] + 0.1
    x = jnp.asarray(np.random.default_rng(0).uniform(
        0, 1, size=(8, cfg.img_size, cfg.img_size, 3)), jnp.float32)
    return cfg, params, x


def test_bn_folding_is_exact(setup):
    cfg, params, x = setup
    conv = params["blocks"][0]["conv1"]
    w, b = R.fold_bn(conv)
    h = jnp.asarray(np.random.default_rng(1).normal(size=(2, 8, 8, w.shape[2])),
                    jnp.float32)
    direct = jax.lax.conv_general_dilated(
        h, conv["w"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    bn = (direct - conv["bn_mean"]) / jnp.sqrt(conv["bn_var"] + 1e-5) \
        * conv["bn_gamma"] + conv["bn_beta"]
    folded = jax.lax.conv_general_dilated(
        h, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")) + b
    assert np.allclose(np.asarray(bn), np.asarray(folded), atol=1e-4)


def test_plan_counts(setup):
    cfg, params, x = setup
    plan = R.build_resnet_plan(cfg)
    counts = count_quant_ops(plan)
    assert counts["saved"] > 0                    # joint < naive
    assert counts["joint_activation_points"] == len(plan.modules)


def test_calibration_and_int_path_agree_with_fake_path(setup):
    cfg, params, x = setup
    q = R.quantize_resnet(params, x, cfg)
    # quantized fake-arithmetic forward tracks the FP forward
    logits_fp = R.resnet_forward(params, x, cfg)
    logits_int = R.resnet_int_forward(q, x, cfg)
    # predictions should agree on most samples (tiny net, 8-bit)
    agree = np.mean(np.argmax(np.asarray(logits_fp), -1) ==
                    np.argmax(np.asarray(logits_int), -1))
    assert agree >= 0.5
    # per-module relative reconstruction errors are small
    rels = [r.rel_error for r in q.report.results.values()]
    assert np.median(rels) < 0.2


def test_calibration_time_is_minutes_not_days(setup):
    """Paper Table 2: minutes.  The smoke net must calibrate in seconds."""
    cfg, params, x = setup
    q = R.quantize_resnet(params, x, cfg)
    assert q.report.total_s < 120


def test_shift_values_in_hardware_range(setup):
    """Paper Fig. 2(b): shifts land in a small range ([1,10] in the RTL)."""
    cfg, params, x = setup
    q = R.quantize_resnet(params, x, cfg)
    for name, spec in q.specs.items():
        if hasattr(spec, "requant_shift"):
            assert -8 <= spec.requant_shift <= 24
