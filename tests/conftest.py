"""Force a 4-device CPU backend for the whole suite.

The shard_map parity harness (``test_shard_map_parity.py``) needs real
multi-device meshes; XLA can split the host CPU into virtual devices, but
only if the flag is set BEFORE jax initializes its backends.  conftest is
imported before any test module, so this is the one reliable place.
Single-device tests are unaffected — default placement stays device 0.

A pre-set ``xla_force_host_platform_device_count`` (e.g. the CI
``multidevice`` job exporting it explicitly) is respected.
"""
import os

_FLAG = "xla_force_host_platform_device_count"
_flags = os.environ.get("XLA_FLAGS", "")
if _FLAG not in _flags:
    os.environ["XLA_FLAGS"] = f"{_flags} --{_FLAG}=4".strip()
