"""Serving-engine end-to-end coverage (DESIGN §9) — the CI `serving`
smoke: a small Poisson trace on CPU must COMPLETE every request and the
continuous-batching paged tokens must MATCH the static-batch dense-cache
oracle exactly at fp32 (greedy).  Plus: preemption round-trip parity,
sampling hooks, report integrity, and the serve() warm-up split.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.qmodel import QuantContext, QuantMode
from repro.models import model as M
from repro.serving import Request, RequestState, ServingEngine

CTX = QuantContext(mode=QuantMode.FP)


def _cfg(**kw):
    cfg = get_smoke_config("qwen3_1_7b").scaled(dtype="float32")
    return dataclasses.replace(cfg, kv_cache_bits=8, **kw)


def _dense_oracle(cfg, params, prompt: np.ndarray, gen: int) -> list:
    """Static-batch oracle: one request, dense cache, greedy decode."""
    p_len = len(prompt)
    logits, cache = M.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                              cfg, CTX, max_seq=p_len + gen)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [int(tok[0, 0])]
    for i in range(gen - 1):
        l, cache = M.decode_step(params, tok, cache,
                                 jnp.asarray(p_len + i, jnp.int32), cfg, CTX)
        tok = jnp.argmax(l, -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    return out


def _check_vs_oracle(cfg, params, reqs, outputs):
    for r in reqs:
        oracle = _dense_oracle(cfg, params, r.prompt, r.max_new_tokens)
        got = outputs[r.rid].tolist()
        # stop-token-free requests emit exactly max_new_tokens
        assert got == oracle[:len(got)] and len(got) == r.max_new_tokens, \
            f"req {r.rid}: engine {got} vs oracle {oracle}"


def _workload(rng, n, vocab, *, p_lo=5, p_hi=20, g_lo=3, g_hi=9,
              arrivals=False):
    t = 0.0
    reqs = []
    for i in range(n):
        t += float(rng.exponential(0.02)) if arrivals else 0.0
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, vocab, size=int(
                rng.integers(p_lo, p_hi))).astype(np.int32),
            max_new_tokens=int(rng.integers(g_lo, g_hi)), arrival=t))
    return reqs


def test_poisson_smoke_completes_and_matches_oracle():
    """The CI `serving` smoke: small Poisson trace, every request
    completes, tokens are exactly the static-batch fp32 oracle's."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _workload(np.random.default_rng(0), 6, cfg.vocab_size,
                     arrivals=True)
    eng = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                        max_model_len=32, chunk=8)
    rep = eng.run(reqs)
    assert rep["completed"] == len(reqs)
    eng.pool.check_invariants()
    assert eng.pool.n_live == 0                    # all blocks returned
    _check_vs_oracle(cfg, params, reqs, eng.outputs())
    # report integrity
    assert rep["gen_tokens"] == sum(r.max_new_tokens for r in reqs)
    assert rep["tokens_per_s"] > 0
    assert rep["ttft_s"]["p50"] is not None
    assert rep["tpot_s"]["p50"] is not None
    # decode steps batched slots: fewer steps than total generated tokens
    assert rep["decode_steps"] < rep["gen_tokens"]
    # compile/steady split is keyed by the DISPATCHED ragged work-list
    # shape (DESIGN §12) — one unified executable serves the whole run
    assert rep["ragged"] and rep["ragged_steps"] > 0
    ragged_keys = [k for k in rep["step_shapes"] if k.startswith("ragged_")]
    assert ragged_keys and "legacy_shapes" not in rep["step_shapes"]
    dec = rep["step_shapes"][ragged_keys[0]]
    assert dec["first_s"] > dec["steady_s"] > 0
    # padding honesty (satellite): the report quantifies bucket waste
    assert rep["dispatched_tokens"] > 0
    assert rep["padding_frac"] == round(
        rep["padded_tokens"] / rep["dispatched_tokens"], 4)


def test_preemption_roundtrip_matches_oracle():
    """Undersized pool: decode growth must evict and resume (recompute),
    and the resumed requests still emit the oracle's exact tokens."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, prompt=rng.integers(
        0, cfg.vocab_size, size=14).astype(np.int32), max_new_tokens=12)
        for i in range(4)]
    # 5 usable blocks x 8 = 40 rows < 2 slots x 26 rows each
    eng = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                        max_model_len=32, num_blocks=6, chunk=8)
    rep = eng.run(reqs)
    assert rep["completed"] == 4
    assert rep["preemptions"] > 0 and rep["pool"]["evictions"] > 0
    eng.pool.check_invariants()
    assert eng.pool.n_live == 0
    _check_vs_oracle(cfg, params, reqs, eng.outputs())


def test_shared_prefix_blocks_physically_shared_and_token_exact():
    """CI `serving` gate for the prefix cache (DESIGN §10): requests
    sharing a prefix must physically share pool blocks (asserted on block
    ids mid-run), a repeated prompt must take the COW path, the report
    must show hit-rate > 0 and >= 1 COW event, and every request decodes
    token-exactly vs the dense-cache oracle through the divergence."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(21)
    shared = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    tail = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
    r0 = Request(rid=0, prompt=shared.copy(), max_new_tokens=8)
    r1 = Request(rid=1, prompt=np.concatenate([shared, tail]),
                 max_new_tokens=6)
    r2 = Request(rid=2, prompt=shared.copy(), max_new_tokens=4)  # repeat
    eng = ServingEngine(cfg, params, CTX, n_slots=3, block_size=8,
                        max_model_len=40, chunk=8)
    eng.submit(r0)
    for _ in range(30):
        eng.step()
        if r0.state is RequestState.DECODE:
            break
    assert r0.state is RequestState.DECODE         # prefix published
    eng.submit(r1)
    eng.submit(r2)
    eng.step()
    b0 = eng.pool.seq_blocks(0)
    b1 = eng.pool.seq_blocks(1)
    b2 = eng.pool.seq_blocks(2)
    # ACCEPTANCE: the same physical pool blocks back the shared prefix
    assert b1[:2] == b0[:2]
    assert (eng.pool.refcount[b0[:2]] >= 2).all()
    # the exact repeat is a FULL-feed hit: first block shared, last block
    # copy-on-written so the re-fed token's write stays private
    assert b2[0] == b0[0] and b2[1] != b0[1]
    while not eng.sched.idle:
        eng.step()
    rep = eng.report()
    assert rep["completed"] == 3
    pc = rep["prefix_cache"]
    assert pc["hit_rate"] > 0 and pc["hits"] >= 4
    assert pc["cow_copies"] >= 1
    # r1 attached 16 prefix tokens, r2 skipped 15 (full hit, one re-fed)
    assert pc["cached_prefill_tokens"] == 31
    assert rep["hwcost"]["requant_ops_avoided_prefix_cache"] > 0
    eng.pool.check_invariants()
    assert eng.pool.n_live == 0 and eng.pool.n_cached > 0
    # token-exactness through sharing + COW divergence
    _check_vs_oracle(cfg, params, [r0, r1, r2], eng.outputs())


def test_shared_prefix_preemption_roundtrip_matches_oracle():
    """Cache + pressure: an undersized pool forces recompute preemption
    while requests share a prefix (and one repeats it exactly).  Resumes
    re-attach whatever published blocks survived, and every request still
    decodes token-exactly vs the dense oracle."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(13)
    shared = rng.integers(0, cfg.vocab_size, size=12).astype(np.int32)
    reqs = []
    for i in range(4):
        tail = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
        reqs.append(Request(rid=i, prompt=np.concatenate([shared, tail]),
                            max_new_tokens=10))
    reqs[2].prompt = reqs[0].prompt.copy()         # exact duplicate
    # 5 usable blocks x 8 = 40 rows < 2 slots x 26 rows each
    eng = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                        max_model_len=32, num_blocks=6, chunk=8)
    rep = eng.run(reqs)
    assert rep["completed"] == 4
    assert rep["preemptions"] > 0 and rep["pool"]["evictions"] > 0
    eng.pool.check_invariants()
    assert eng.pool.n_live == 0
    _check_vs_oracle(cfg, params, reqs, eng.outputs())


def test_prefix_cache_off_matches_cached_engine_greedy():
    """A/B at equal pool size: the cache changes WHAT work runs, never
    the tokens — prefix_cache=False produces identical greedy outputs."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(17)
    shared = rng.integers(0, cfg.vocab_size, size=10).astype(np.int32)

    def workload():
        return [Request(rid=i, prompt=np.concatenate(
            [shared, rng2.integers(0, cfg.vocab_size, size=3 + i)
             .astype(np.int32)]), max_new_tokens=5) for i in range(3)]

    rng2 = np.random.default_rng(19)
    reqs_a = workload()
    rng2 = np.random.default_rng(19)
    reqs_b = workload()
    on = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                       max_model_len=32, chunk=8, prefix_cache=True)
    on.run(reqs_a)
    off = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                        max_model_len=32, chunk=8, prefix_cache=False)
    off.run(reqs_b)
    assert off.pool.cache is None
    for rid in range(3):
        assert on.outputs()[rid].tolist() == off.outputs()[rid].tolist()
    assert on.pool.cache.stats.hits > 0            # the cache did engage


def test_stop_token_and_max_len():
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    # find what greedy emits first, then use it as the stop token
    first = _dense_oracle(cfg, params, prompt, 1)[0]
    reqs = [
        Request(rid=0, prompt=prompt, max_new_tokens=10, stop_token=first),
        # prompt 28 + max_new 4 == max_model_len 32: must clamp, not hang
        Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, size=28)
                .astype(np.int32), max_new_tokens=4),
    ]
    eng = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                        max_model_len=32, chunk=8)
    rep = eng.run(reqs)
    assert rep["completed"] == 2
    outs = eng.outputs()
    assert outs[0].tolist() == [first]             # stopped immediately
    assert len(outs[1]) == 4


def test_sampling_hooks_deterministic():
    """temperature/top-k sampling: tokens stay in-vocab and the whole run
    is reproducible from the engine seed."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    def run():
        rng = np.random.default_rng(7)
        reqs = _workload(rng, 4, cfg.vocab_size)
        for r in reqs:
            r.temperature = 0.8
        eng = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                            max_model_len=32, chunk=8, top_k=5, seed=42)
        eng.run(reqs)
        return eng.outputs()

    a, b = run(), run()
    for rid in a:
        assert a[rid].tolist() == b[rid].tolist()
        assert (a[rid] >= 0).all() and (a[rid] < cfg.vocab_size).all()


def test_per_request_top_k_honored():
    """Request.top_k is applied per slot: top_k=1 with temperature > 0
    degenerates to greedy (the only survivor of the k-filter is the
    argmax), so its tokens must equal the greedy oracle's while riding in
    the same batch as full-vocab sampled requests."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    reqs = _workload(rng, 3, cfg.vocab_size, g_lo=4, g_hi=7)
    reqs[0].temperature = 1.0
    reqs[0].top_k = 1                              # == greedy
    reqs[1].temperature = 1.0                      # full-vocab sampling
    eng = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                        max_model_len=32, chunk=8, seed=3)
    eng.run(reqs)
    outs = eng.outputs()
    oracle = _dense_oracle(cfg, params, reqs[0].prompt,
                           reqs[0].max_new_tokens)
    assert outs[0].tolist() == oracle
    assert outs[2].tolist() == _dense_oracle(cfg, params, reqs[2].prompt,
                                             reqs[2].max_new_tokens)


def test_mixed_greedy_and_sampled_slots():
    """temperature=0 rows in a sampled batch stay EXACTLY greedy: the
    fixed-shape sampler must not perturb greedy requests."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(9)
    reqs = _workload(rng, 4, cfg.vocab_size, g_lo=4, g_hi=7)
    reqs[1].temperature = 1.0
    reqs[3].temperature = 1.0
    eng = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                        max_model_len=32, chunk=8, seed=1)
    eng.run(reqs)
    outs = eng.outputs()
    for r in (reqs[0], reqs[2]):                   # the greedy ones
        oracle = _dense_oracle(cfg, params, r.prompt, r.max_new_tokens)
        assert outs[r.rid].tolist() == oracle


def test_hwcost_requant_accounting():
    """Write-once accounting: performed ops == KV elements written once
    per (real) token; avoided ops grow with live context per decode step —
    and the Table 5 energies order accordingly."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    req = Request(rid=0, prompt=np.arange(10, dtype=np.int32) % cfg.vocab_size,
                  max_new_tokens=6)
    eng = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                        max_model_len=32, chunk=8)
    rep = eng.run([req])
    per_tok = (cfg.n_layers * cfg.n_kv_heads * cfg.resolved_head_dim * 2)
    hw = rep["hwcost"]
    # 10 prompt + 5 decode-fed tokens, each quantized exactly once
    assert hw["requant_ops_performed"] == 15 * per_tok
    # dequant-per-step counterfactual: sum of live context over 5 steps
    assert hw["requant_ops_avoided"] == sum(
        11 + i for i in range(5)) * per_tok
    assert (hw["energy_uj_bit_shift"]
            < hw["energy_uj_if_requant_per_step"]
            < hw["energy_uj_if_scaling_factor"])


@pytest.mark.parametrize("spec_k", [0, 3])
def test_ragged_engine_token_identical_to_legacy(spec_k):
    """ACCEPTANCE (DESIGN §12): the unified ragged step is a pure
    dataflow refactor — same workload, same params, greedy outputs are
    token-for-token IDENTICAL to the retired per-shape engine, with
    speculation off and on."""
    from repro.serving.spec import CallableDrafter
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    def run(ragged):
        reqs = _workload(np.random.default_rng(23), 5, cfg.vocab_size,
                         arrivals=True)
        # deterministic always-proposing drafter so BOTH engines hit the
        # verify path (ngram rarely fires on short random prompts)
        drafter = CallableDrafter(lambda h, k: [int(h[-1])] * k)
        eng = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                            max_model_len=32, chunk=8, spec_k=spec_k,
                            drafter=drafter, ragged=ragged)
        rep = eng.run(reqs)
        assert rep["completed"] == len(reqs)
        eng.pool.check_invariants()
        assert eng.pool.n_live == 0
        return eng.outputs(), rep

    got_r, rep_r = run(True)
    got_l, rep_l = run(False)
    assert rep_r["ragged"] and not rep_l["ragged"]
    assert rep_r["ragged_steps"] > 0 and rep_l["ragged_steps"] == 0
    for rid in got_l:
        assert got_r[rid].tolist() == got_l[rid].tolist(), f"req {rid}"
    if spec_k:
        assert rep_r["spec_steps"] > 0 and rep_l["spec_steps"] > 0
        assert (rep_r["speculative"]["drafted_tokens"]
                == rep_l["speculative"]["drafted_tokens"] > 0)


def test_ragged_engine_token_identical_through_preemption_and_sharing():
    """The hard path: an undersized pool forces eviction/recompute while
    requests share (and one exactly repeats) a prefix — the ragged
    scheduler makes DIFFERENT step-level choices than the legacy phase
    loop, but greedy per-request token streams must not change."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(29)
    shared = rng.integers(0, cfg.vocab_size, size=12).astype(np.int32)

    def workload():
        r2 = np.random.default_rng(31)
        reqs = [Request(rid=i, prompt=np.concatenate(
            [shared, r2.integers(0, cfg.vocab_size, size=4)
             .astype(np.int32)]), max_new_tokens=10) for i in range(4)]
        reqs[2].prompt = reqs[0].prompt.copy()     # exact duplicate
        return reqs

    outs = {}
    for ragged in (True, False):
        eng = ServingEngine(cfg, params, CTX, n_slots=2, block_size=8,
                            max_model_len=32, num_blocks=6, chunk=8,
                            ragged=ragged)
        rep = eng.run(workload())
        assert rep["completed"] == 4
        assert rep["preemptions"] > 0
        assert rep["prefix_cache"]["hits"] > 0
        eng.pool.check_invariants()
        assert eng.pool.n_live == 0
        outs[ragged] = eng.outputs()
    for rid in outs[False]:
        assert outs[True][rid].tolist() == outs[False][rid].tolist()
    _check_vs_oracle(cfg, params, workload(), outs[True])


def test_ragged_padding_strictly_less_than_bucketed():
    """Satellite regression: on a mixed prefill+decode workload at
    serving scale, the ragged work-list dispatches strictly fewer padded
    tokens than the per-shape bucketed engine — the perf claim the
    tentpole exists for, held token-identical at the same time."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    def run(ragged):
        # staggered arrivals keep prefill chunks and decode rows live in
        # the same steps — the mix the bucketed engine pads worst
        reqs = _workload(np.random.default_rng(37), 10, cfg.vocab_size,
                         p_lo=6, p_hi=24, g_lo=4, g_hi=10, arrivals=True)
        eng = ServingEngine(cfg, params, CTX, n_slots=8, block_size=8,
                            max_model_len=64, chunk=16,
                            prefill_token_budget=32, ragged=ragged)
        rep = eng.run(reqs)
        assert rep["completed"] == len(reqs)
        return eng.outputs(), rep

    got_r, rep_r = run(True)
    got_l, rep_l = run(False)
    for rid in got_l:
        assert got_r[rid].tolist() == got_l[rid].tolist(), f"req {rid}"
    assert rep_r["padded_tokens"] < rep_l["padded_tokens"], (
        rep_r["padded_tokens"], rep_l["padded_tokens"])
    assert rep_r["padding_frac"] < rep_l["padding_frac"]


def test_serve_warmup_reports_compile_separately():
    """Satellite: serve() AOT-compiles, so prefill_s / decode_s_per_tok
    are steady-state and compile time is its own field."""
    from repro.launch.serve import serve
    out = serve("qwen3_1_7b", batch=2, prompt_len=8, gen=4, mode="fp",
                calibrate=False)
    assert out["compile_prefill_s"] > 0 and out["compile_decode_s"] > 0
    assert out["prefill_s"] > 0 and out["decode_s_per_tok"] > 0
    # steady per-token decode must not contain a multi-second jit compile
    assert out["decode_s_per_tok"] < out["compile_decode_s"]
